"""Paper Fig 1: loss curves are blind to silent bugs.

Trains the single-device reference and a distributed candidate with an
injected wrong-loss-scaling bug side by side: the loss/grad-norm curves stay
within a few percent for hundreds of steps, while a single TTrace iteration
flags the bug immediately.

    PYTHONPATH=src python examples/loss_curve_blindness.py [steps]
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import dataclasses
import sys
import time

import jax
import numpy as np

from repro.configs.base import get_config
from repro.core.harness import make_model_runner, ttrace_check
from repro.data.synthetic import make_batch
from repro.launch.steps import make_train_step
from repro.models.model import Model
from repro.optim.adamw import AdamW
from repro.parallel.api import (ParallelConfig, make_candidate_runner,
                                make_plain_train_step)

STEPS = int(sys.argv[1]) if len(sys.argv) > 1 else 120
BUG = "dp_wrong_loss_scale"

cfg = dataclasses.replace(get_config("gpt-paper").reduced(),
                          n_layers=2, vocab=512, tie_embeddings=True)
model = Model(cfg)
params = model.init(jax.random.PRNGKey(0))
opt = AdamW(lr=3e-3)
pcfg = ParallelConfig(dp=2, tp=2, bugs=frozenset([BUG]))

ref_step = jax.jit(make_train_step(model, opt))
cand_step, prep, cparams, cstate = make_plain_train_step(cfg, pcfg, params,
                                                         opt)
rp, rs = params, opt.init(params)
print(f"step | ref loss | buggy-candidate loss | rel gap")
rh, ch = [], []
for step in range(STEPS):
    batch = make_batch(cfg, 4, 32, step=step)
    rp, rs, met = ref_step(rp, rs, batch)
    cparams, cstate, closs = cand_step(cparams, cstate, prep(batch))
    rh.append(float(met["loss"]))
    ch.append(float(closs))
    if step % 20 == 0 or step == STEPS - 1:
        w = min(20, len(rh))
        gap = abs(np.mean(ch[-w:]) - np.mean(rh[-w:])) / np.mean(rh[-w:])
        print(f"{step:4d} | {rh[-1]:.4f}  | {ch[-1]:.4f}              "
              f"| {gap*100:.2f}%")

w = 20
gap = abs(np.mean(ch[-w:]) - np.mean(rh[-w:])) / np.mean(rh[-w:])
print(f"\nafter {STEPS} steps the smoothed loss gap is {gap*100:.2f}% — "
      f"{'would NOT' if gap < 0.03 else 'would'} trip a 3% alarm.")

t0 = time.time()
res = ttrace_check(make_model_runner(model, params, opt, opt.init(params)),
                   make_candidate_runner(cfg, pcfg, params, opt,
                                         opt.init(params)),
                   make_batch(cfg, 4, 32), localize=False)
print(f"TTrace: ONE iteration in {time.time()-t0:.1f}s -> "
      f"{'detected the bug' if not res.passed else 'no bug?!'} "
      f"({len(res.report.flagged)} tensors flagged)")

# the streaming supervisor rides along with the SAME run and names the step
from repro.supervise import Supervisor, SuperviseConfig

t0 = time.time()
sup = Supervisor(model, cfg, pcfg, AdamW(lr=3e-3), params=params,
                 scfg=SuperviseConfig(steps=min(STEPS, 8)),
                 batch_size=4, seq_len=32)
sres = sup.run()
print(f"supervisor: online over the same run in {time.time()-t0:.1f}s -> "
      f"first flagged step {sres.first_flagged_step}, first bad step "
      f"{sres.first_bad_step} (localized: {sres.localized_module}) — "
      f"the loss curve was still within {gap*100:.2f}% after {STEPS} steps")
