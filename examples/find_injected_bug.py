"""Detect and localize a real silent bug with TTrace (paper §3 workflow).

Injects paper bug 1 — the tensor-parallel vocab embedding uses a wrong
ownership mask — into the manual-collectives distributed GPT, then runs the
full TTrace pipeline: threshold estimation, differential testing, and
rewrite-mode localization.

    PYTHONPATH=src python examples/find_injected_bug.py [bug_id]
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import dataclasses
import sys

import jax

from repro.bugs.registry import BUGS
from repro.configs.base import get_config
from repro.core.harness import make_model_runner, ttrace_check
from repro.data.synthetic import make_batch
from repro.models.model import Model
from repro.optim.adamw import AdamW
from repro.parallel.api import ParallelConfig, make_candidate_runner

bug_id = sys.argv[1] if len(sys.argv) > 1 else "tp_wrong_embedding_mask"
spec = BUGS[bug_id]
print(f"injecting: {bug_id} [{spec.btype}] — {spec.description}\n"
      f"  (paper analogue: {spec.paper_analogue})")

cfg = dataclasses.replace(get_config("gpt-paper").reduced(),
                          n_layers=2, vocab=512, tie_embeddings=True)
model = Model(cfg)
params = model.init(jax.random.PRNGKey(0))
opt = AdamW(lr=1e-3)
state = opt.init(params)
batch = make_batch(cfg, 4, 32)

req = set(spec.requires)
pcfg = ParallelConfig(dp=2, cp=2 if "cp" in req else 1, tp=2,
                      sp="sp" in req, zero1="zero1" in req,
                      bugs=frozenset([bug_id]))

reference = make_model_runner(model, params, opt, state)
candidate = make_candidate_runner(cfg, pcfg, params, opt, state)

result = ttrace_check(reference, candidate, batch, localize=True)
print()
print(result.summary())
print(f"\nexpected module: {spec.expected_module}")
print(f"TTrace localized: {result.localized_module}")
