"""Paper §6.2: sweep-test parallelism combinations with TTrace.

The paper found its 3 NEW Megatron bugs by sweeping 4D-parallelism
combinations and TTrace-checking each against the single-device reference.
This driver does the same against our manual-collectives backend: every
(dp, cp, tp, sp, zero1) combination that fits the forced host devices is
checked in one iteration; any FAIL is a silent bug in the distribution
layer.  (All combinations pass on the shipped code — the bugs only appear
when injected via --bug.)

    PYTHONPATH=src python examples/parallelism_sweep.py [--bug <bug_id>]
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import argparse
import dataclasses
import itertools
import time

import jax

from repro.bugs.registry import BUGS
from repro.configs.base import get_config
from repro.core.harness import make_model_runner, ttrace_check
from repro.data.synthetic import make_batch
from repro.models.model import Model
from repro.optim.adamw import AdamW
from repro.parallel.api import ParallelConfig, make_candidate_runner

ap = argparse.ArgumentParser()
ap.add_argument("--bug", default=None, choices=[None, *BUGS])
ap.add_argument("--max-devices", type=int, default=8)
args = ap.parse_args()
bugs = frozenset([args.bug]) if args.bug else frozenset()

cfg = dataclasses.replace(get_config("gpt-paper").reduced(),
                          n_layers=2, vocab=512, tie_embeddings=True)
model = Model(cfg)
params = model.init(jax.random.PRNGKey(0))
opt = AdamW(lr=1e-3)
state = opt.init(params)
batch = make_batch(cfg, 4, 32)
reference = make_model_runner(model, params, opt, state)

combos = []
for dp, cp, tp in itertools.product((1, 2), (1, 2), (1, 2)):
    for sp in (False, True):
        for z1 in (False, True):
            pc = ParallelConfig(dp=dp, cp=cp, tp=tp, sp=sp, zero1=z1,
                                bugs=bugs)
            if pc.n_devices < 2 or pc.n_devices > args.max_devices:
                continue
            if sp and tp == 1:
                continue
            combos.append(pc)

print(f"sweeping {len(combos)} parallelism combinations "
      f"({'bug: ' + args.bug if args.bug else 'no injected bug'})\n")
print(f"{'dp':>3} {'cp':>3} {'tp':>3} {'sp':>5} {'zero1':>6}  result")
n_fail = 0
for pc in combos:
    t0 = time.time()
    cand = make_candidate_runner(cfg, pc, params, opt, state)
    res = ttrace_check(reference, cand, batch, localize=False)
    ok = res.passed
    n_fail += (not ok)
    print(f"{pc.dp:>3} {pc.cp:>3} {pc.tp:>3} {str(pc.sp):>5} "
          f"{str(pc.zero1):>6}  {'PASS' if ok else 'FAIL'} "
          f"({len(res.report.flagged)} flagged, {time.time()-t0:.0f}s)")
print(f"\n{len(combos) - n_fail}/{len(combos)} combinations equivalent to "
      f"the reference" + (" — bug detected where applicable" if n_fail else ""))
