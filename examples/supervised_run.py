"""Catch an update-path bug mid-run that the single-step check misses.

``zero_skipped_update`` (paper bug 9): the ZeRO-1 all-gather returns the
pre-update shard for the last rank's partition — those parameters silently
never train.  At a fine-tuning-scale learning rate the per-step parameter
gap sits BELOW the FP-noise threshold, so the paper's one-iteration check
passes; but the skipped partition falls further behind every step while
benign round-off does not accumulate, so the growing gap feeds the forward
pass and crosses the supervisor's online thresholds a few steps in — and
bisection pins down the exact first step the drift became distinguishable
from floating point.

    PYTHONPATH=src python examples/supervised_run.py [steps]
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import dataclasses
import sys

import jax

from repro.bugs.registry import BUGS
from repro.configs.base import get_config
from repro.core.harness import make_model_runner, ttrace_check
from repro.data.synthetic import make_batch
from repro.models.model import Model
from repro.optim.adamw import AdamW
from repro.parallel.api import ParallelConfig, make_candidate_runner
from repro.supervise import Supervisor, SuperviseConfig

STEPS = int(sys.argv[1]) if len(sys.argv) > 1 else 16
BUG = "zero_skipped_update"
LR = 1e-7     # fine-tuning scale: one skipped update is within FP noise

spec = BUGS[BUG]
print(f"injected: {BUG} [{spec.btype}] — {spec.description}")
print(f"lr={LR:.0e} -> a single step's missing update is below the "
      f"FP-round-off threshold\n")

cfg = dataclasses.replace(get_config("gpt-paper").reduced(),
                          n_layers=2, vocab=512, tie_embeddings=True)
model = Model(cfg)
params = model.init(jax.random.PRNGKey(0))
pcfg = ParallelConfig(dp=2, tp=2, zero1=True, bugs=frozenset([BUG]))

# --- the paper's single-step check: blind at this learning rate -------------
opt = AdamW(lr=LR)
one = ttrace_check(
    make_model_runner(model, params, opt, opt.init(params)),
    make_candidate_runner(cfg, pcfg, params, opt, opt.init(params)),
    make_batch(cfg, 4, 32), localize=False)
print(f"single-step ttrace_check: {'PASS' if one.passed else 'FAIL'} "
      f"({len(one.report.flagged)} tensors flagged) "
      f"{'— the bug slips through' if one.passed else ''}")

# --- the streaming supervisor: drift accumulates, noise does not ------------
sup = Supervisor(model, cfg, pcfg, AdamW(lr=LR), params=params,
                 scfg=SuperviseConfig(steps=STEPS, check_every=2,
                                      ckpt_every=4),
                 log_fn=print)
res = sup.run()
print()
print(res.summary())
if res.flagged:
    print(f"\nthe one-shot check said PASS; supervising {res.steps_run} "
          f"steps caught the drift at step {res.first_flagged_step} and "
          f"bisected the first bad step to {res.first_bad_step} "
          f"(localized: {res.localized_module})")
