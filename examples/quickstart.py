"""Quickstart: train a small model end-to-end, then verify the training step
with TTrace (reference vs re-jitted candidate must be equivalent).

    PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.configs.base import get_config
from repro.core.harness import make_model_runner, ttrace_check
from repro.data.synthetic import make_batch
from repro.launch.steps import make_train_step
from repro.models.model import Model
from repro.optim.adamw import AdamW

cfg = get_config("tinyllama-1.1b").reduced()
model = Model(cfg)
params = model.init(jax.random.PRNGKey(0))
opt = AdamW(lr=3e-4)
state = opt.init(params)
step = jax.jit(make_train_step(model, opt))

print(f"training reduced {cfg.name} "
      f"({sum(x.size for x in jax.tree.leaves(params))/1e6:.1f}M params)")
for i in range(20):
    batch = make_batch(cfg, 8, 64, step=i)
    params, state, metrics = step(params, state, batch)
    if i % 5 == 0:
        print(f"  step {i}: loss {float(metrics['loss']):.4f}")

# TTrace: one-iteration differential check (paper §3)
ref = make_model_runner(model, params, opt, state)
cand = make_model_runner(model, params, opt, state)
result = ttrace_check(ref, cand, make_batch(cfg, 8, 64), localize=False)
print("\nTTrace check (candidate == reference):",
      "PASS" if result.passed else "FAIL")
print(f"  {len(result.report.records)} tensors compared, "
      f"{len(result.report.flagged)} flagged")
