"""Paper §5 / Fig 7: estimate the expected FP round-off thresholds of a
model by running the reference twice with an epsilon-perturbed input, and
print the per-layer error-accumulation curve (normalized by machine eps).

    PYTHONPATH=src python examples/threshold_estimation.py [arch]
"""
import dataclasses
import sys

import jax

from repro.configs.base import get_config
from repro.core.harness import make_model_runner
from repro.core.thresholds import MACHINE_EPS, estimate_thresholds
from repro.data.synthetic import make_batch
from repro.models.model import Model
from repro.optim.adamw import AdamW

arch = sys.argv[1] if len(sys.argv) > 1 else "gpt-paper"
cfg = dataclasses.replace(get_config(arch).reduced(), n_layers=8,
                          compute_dtype="bfloat16")
eps = MACHINE_EPS["bfloat16"]
model = Model(cfg)
params = model.init(jax.random.PRNGKey(0))
opt = AdamW(lr=1e-3)
runner = make_model_runner(model, params, opt, opt.init(params))
batch = make_batch(cfg, 2, 64)

thr, base = estimate_thresholds(runner, batch, eps)
print(f"arch={cfg.name} (reduced, 8 layers, bf16) — estimated FP round-off "
      f"error per tensor, in units of bf16 eps ({eps:.2e}):\n")
print(f"{'tensor':48s} {'act':>8s} {'act_grad':>9s}")
for name in base.meta["fwd_order"]:
    a = thr.per_tensor["activation"].get(name)
    g = thr.per_tensor["act_grad"].get(name)
    if a is None:
        continue
    print(f"{name:48s} {a/eps:8.2f} {(g or 0)/eps:9.2f}")
print("\nthe slow growth with depth is the smoothness property "
      "(paper Thm 5.1/5.2) that makes thresholding work.")
