"""Inference-mode TTrace — the paper's §7 future-work direction, implemented:
differential checking of the DECODE path (one-token steps + caches).

Reference = naive MLA decode (materialized per-head K/V); candidate = the
production absorbed-latent decode.  They are independent implementations of
the same math, exactly the reference/candidate relationship of the paper.
"""
import contextlib
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.core.harness import make_decode_runner, ttrace_check
from repro.core.thresholds import MACHINE_EPS
from repro.data.synthetic import make_batch
from repro.models import attention as attn_mod
from repro.models.model import Model


@contextlib.contextmanager
def _mla_impl(impl, bugs=frozenset()):
    old = (attn_mod.MLA_DECODE_IMPL, attn_mod.MLA_DECODE_BUGS)
    attn_mod.MLA_DECODE_IMPL, attn_mod.MLA_DECODE_BUGS = impl, bugs
    try:
        yield
    finally:
        attn_mod.MLA_DECODE_IMPL, attn_mod.MLA_DECODE_BUGS = old


def _runner(model, params, impl, bugs=frozenset()):
    def decode_fn(p, cache, toks, pos):
        with _mla_impl(impl, bugs):
            return model.decode_step(p, cache, toks, pos)
    return make_decode_runner(model, params, decode_fn=decode_fn)


@pytest.fixture(scope="module")
def mla_setup():
    cfg = get_config("deepseek-v2-236b").reduced()
    cfg = dataclasses.replace(cfg, moe=None, arch_type="dense")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = {"tokens": np.asarray(
        make_batch(cfg, 2, 12)["tokens"])}
    return model, params, batch


def test_absorbed_vs_naive_mla_decode_equivalent(mla_setup):
    """Two independent MLA decode implementations agree within FP floor."""
    model, params, batch = mla_setup
    ref = _runner(model, params, "naive")
    cand = _runner(model, params, "absorbed")
    res = ttrace_check(ref, cand, batch, estimate=False, localize=False,
                       margin=64.0)
    assert res.passed, res.report.summary()


def test_stale_rope_position_decode_bug_detected(mla_setup):
    """Serving bug: query rope uses pos-1 — silent (finite logits, plausible
    decoding) but every step's logits drift; TTrace flags it from step 1."""
    model, params, batch = mla_setup
    ref = _runner(model, params, "naive")
    buggy = _runner(model, params, "absorbed",
                    bugs=frozenset(["decode_stale_rope_pos"]))
    res = ttrace_check(ref, buggy, batch, estimate=False, localize=False,
                       margin=64.0)
    assert not res.passed
    assert all(np.isfinite(v).all()
               for v in res.candidate.activations.values())
    first = res.report.first_flagged_activation()
    # step 0 attends only to itself (pos clamped) — drift begins at step 1+
    assert first.name.startswith("decode.t")
