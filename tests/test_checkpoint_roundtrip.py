"""Checkpoint round-trip exactness: dtypes (bf16/fp8), 0-d leaves, shard
splitting, and template-driven device placement — the contract the
supervisor's bisection replay depends on."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.store import (load_checkpoint, load_checkpoint_named,
                                    save_checkpoint)


def _tree():
    return {
        "bf16": jnp.arange(16, dtype=jnp.bfloat16).reshape(4, 4),
        "fp8": jnp.full((3, 5), 0.5, jnp.float8_e4m3fn),
        "f32": jnp.linspace(0, 1, 12, dtype=jnp.float32).reshape(3, 4),
        "i32_scalar": jnp.asarray(7, jnp.int32),
        "bf16_scalar": jnp.asarray(1.25, jnp.bfloat16),
        "bool": jnp.asarray([True, False, True]),
    }


def test_multi_dtype_roundtrip_exact(tmp_path):
    tree = _tree()
    save_checkpoint(str(tmp_path), tree, step=11, extra={"tag": "x"})
    out, step, extra = load_checkpoint(str(tmp_path), tree)
    assert step == 11 and extra == {"tag": "x"}
    for name, ref in tree.items():
        got = out[name]
        assert isinstance(got, jax.Array), name
        assert got.dtype == ref.dtype, name
        assert got.shape == ref.shape, name
        # bit-exact: compare raw bytes, not values (NaN-safe, fp8-safe)
        assert (np.asarray(got).tobytes()
                == np.asarray(ref).tobytes()), name


def test_sharded_exotic_leaf_roundtrip(tmp_path):
    """A bf16 leaf split across multiple shard files restores exactly."""
    tree = {"w": jnp.arange(4096, dtype=jnp.bfloat16).reshape(64, 64)}
    save_checkpoint(str(tmp_path), tree, shard_bytes=1024)
    import glob
    import os
    assert len(glob.glob(os.path.join(str(tmp_path), "shard_*.npz"))) > 1
    out, _, _ = load_checkpoint(str(tmp_path), tree)
    assert out["w"].dtype == jnp.bfloat16
    np.testing.assert_array_equal(np.asarray(out["w"], np.float32),
                                  np.asarray(tree["w"], np.float32))


def test_load_checkpoint_named_template_free(tmp_path):
    tree = _tree()
    save_checkpoint(str(tmp_path), tree, step=3)
    named, step, _ = load_checkpoint_named(str(tmp_path))
    assert step == 3 and set(named) == set(tree)
    for name, ref in tree.items():
        assert named[name].dtype == np.asarray(ref).dtype
        assert (named[name].tobytes()
                == np.asarray(ref).tobytes()), name


def test_default_device_restore_stays_uncommitted(tmp_path):
    """Plain default-device trees restore like fresh jnp.asarray arrays, so
    downstream jits (e.g. one containing a shard_map over a mesh) remain
    free to place them — a committed single-device restore would conflict."""
    tree = {"a": jnp.ones((4, 4), jnp.float32)}
    save_checkpoint(str(tmp_path), tree)
    out, _, _ = load_checkpoint(str(tmp_path), tree)
    assert not out["a"]._committed
    assert out["a"].sharding == tree["a"].sharding


def test_optimizer_state_roundtrip(tmp_path):
    """The supervisor's actual checkpoint payload: (params, opt_state)."""
    from repro.optim.adamw import AdamW
    params = {"w": jnp.linspace(-1, 1, 20, dtype=jnp.float32).reshape(4, 5),
              "b": jnp.zeros((5,), jnp.float32)}
    opt = AdamW(lr=1e-3)
    st = opt.init(params)
    p2, st2, _ = opt.update(params, jax.tree.map(jnp.ones_like, params), st)
    save_checkpoint(str(tmp_path), (p2, st2), step=1)
    (rp, rs), _, _ = load_checkpoint(str(tmp_path), (p2, st2))
    for a, b in zip(jax.tree.leaves((p2, st2)), jax.tree.leaves((rp, rs))):
        assert np.asarray(a).tobytes() == np.asarray(b).tobytes()
