"""Fault tolerance: journaled resume, watchdogged checks, graceful
degradation, checksummed payloads, and the loud-fault injection harness.

Fast units cover the journal format (torn-tail / CRC-stop reads, payload
roundtrips, the resume-step predicate), the fault-spec refusal path (CLI
and ``make_injector``), the background writer's loud-death contract, the
watchdog escalation ladder, the degradation controller, LOUD NaN
classification and checkpoint checksums.  The slow lane runs the real
supervised loop: crash/resume convergence (property-tested over the crash
step), a flagged run resuming to the same first-bad-step, every registered
fault injected end to end, and a true SIGKILL through the CLI.
"""
import json
import os
import signal
import subprocess
import sys
import threading
import time

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_compat import given, settings, strategies as st

from repro.checkpoint.store import (MANIFEST, ChecksumError,
                                    load_checkpoint_named, save_checkpoint)
from repro.core import canonical as C
from repro.core.checker import Report, report_from_errs
from repro.core.collector import Trace
from repro.core.thresholds import Thresholds
from repro.supervise import (FAULTS, BackgroundWriter, BoundaryTimeout,
                             CheckpointKeeper, CheckTimeout,
                             DegradationController, Journal, JournalState,
                             Watchdog, WriterDeath, journal_path,
                             make_injector, wait_ready)
from repro.supervise.journal import (report_from_payload, report_to_payload,
                                     thresholds_from_payload,
                                     thresholds_to_payload)
from repro.supervise.store import TraceRing

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


class Boom(Exception):
    """In-process stand-in for SIGKILL: the journal fsyncs every record, so
    an abrupt abort at the crash site is indistinguishable from the
    signal."""


def _boom():
    raise Boom("injected crash")


def _mk_trace(val: float, seed: int = 0) -> Trace:
    rng = np.random.default_rng(seed)
    tr = Trace()
    base = rng.standard_normal((4, 8)).astype(np.float32)
    tr.activations = {"m1/input": base + val, "m1/output": 2 * base + val}
    tr.act_grads = {"m1/input": base - val}
    tr.param_grads = {"m1.w": base * 3 + val}
    tr.main_grads = {"m1.w": base * 3 + val}
    tr.params_post = {"m1.w": base * 5 + val}
    tr.loss = float(val)
    tr.grad_norm = 1.0
    tr.meta["fwd_order"] = ["m1/input", "m1/output"]
    return tr


# ---------------------------------------------------------------------------
# journal format
# ---------------------------------------------------------------------------

def test_journal_roundtrip(tmp_path):
    path = journal_path(str(tmp_path))
    j = Journal(path)
    j.append("start", steps=8, check_every=1)
    j.append("step", step=0, checked=True)
    j.append("verdict", step=0, report=None)
    j.close()
    events = Journal.read(path)
    assert [e["t"] for e in events] == ["start", "step", "verdict"]
    assert events[0]["steps"] == 8
    assert events[1] == {"t": "step", "step": 0, "checked": True}


def test_journal_append_is_thread_safe(tmp_path):
    path = journal_path(str(tmp_path))
    j = Journal(path, fsync=False)     # fsync off: the race, not the disk
    threads = [threading.Thread(
        target=lambda i=i: [j.append("step", step=i * 100 + k)
                            for k in range(50)]) for i in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    j.close()
    events = Journal.read(path)
    assert len(events) == 200          # no torn/interleaved lines
    assert {e["step"] for e in events} == {i * 100 + k
                                           for i in range(4)
                                           for k in range(50)}


def test_journal_read_stops_at_torn_tail(tmp_path):
    path = journal_path(str(tmp_path))
    j = Journal(path)
    j.append("start", steps=4)
    j.append("step", step=0)
    j.close()
    with open(path, "a") as f:
        f.write('{"t":"step","step"')          # SIGKILL mid-append
    events = Journal.read(path)
    assert [e["t"] for e in events] == ["start", "step"]


def test_journal_read_stops_at_crc_mismatch(tmp_path):
    path = journal_path(str(tmp_path))
    j = Journal(path)
    for k in range(3):
        j.append("step", step=k)
    j.close()
    lines = open(path).read().splitlines(keepends=True)
    lines[1] = lines[1].replace('"step":1', '"step":9')   # payload rot
    with open(path, "w") as f:
        f.writelines(lines)
    events = Journal.read(path)
    # everything before the rotted record is trusted, nothing after
    assert [e.get("step") for e in events] == [0]


def test_report_payload_roundtrip():
    thr = Thresholds(eps=2.0 ** -24)
    entries = [(C.KIND_ACT, "m1/output", None),
               (C.KIND_PARAM_GRAD, "m1.w", None)]
    rep = report_from_errs(entries, [float("nan"), 1e-9], thr,
                           missing=["act:x missing from candidate"])
    back = report_from_payload(report_to_payload(rep))
    assert [(r.kind, r.name, r.flagged, r.note) for r in back.records] \
        == [(r.kind, r.name, r.flagged, r.note) for r in rep.records]
    assert np.isnan(back.records[0].rel_err)
    assert back.missing == rep.missing
    assert back.localized == rep.localized
    assert report_from_payload(report_to_payload(None)) is None


def test_thresholds_payload_roundtrip():
    thr = Thresholds(eps=2.0 ** -10, margin=4.0,
                     per_tensor={C.KIND_ACT: {"m1/output": 3e-4}})
    back = thresholds_from_payload(thresholds_to_payload(thr))
    assert back.eps == thr.eps and back.margin == thr.margin
    assert back.threshold(C.KIND_ACT, "m1/output") \
        == thr.threshold(C.KIND_ACT, "m1/output")


# ---------------------------------------------------------------------------
# resume-state reconstruction
# ---------------------------------------------------------------------------

def _state(events):
    return JournalState(events)


def test_resume_step_requires_verdicts_below_checkpoint():
    events = [{"t": "start", "steps": 8, "reestimate_every": 0}]
    for k in range(6):
        events.append({"t": "step", "step": k, "checked": True})
    for k in range(4):
        events.append({"t": "verdict", "step": k, "report": None})
    js = _state(events)
    # checkpoint 4 is durable: verdicts 0..3 journaled; 6 is not (4,5
    # died in flight with the process)
    assert js.resume_step([0, 2, 4, 6]) == 4
    # drop verdict 3: even checkpoint 4 would skip a dead in-flight check
    js2 = _state([e for e in events
                  if not (e["t"] == "verdict" and e["step"] == 3)])
    assert js2.resume_step([0, 2, 4, 6]) == 2


def test_resume_step_requires_settled_epochs():
    events = [{"t": "start", "steps": 8, "reestimate_every": 2}]
    for k in range(6):
        events.append({"t": "step", "step": k, "checked": False})
    thr = thresholds_to_payload(Thresholds(eps=2.0 ** -24))
    events.append({"t": "epoch", "from_step": 2, "thresholds": thr,
                   "kind_mult": {}, "reestimated": True})
    js = _state(events)
    # the step-4 re-estimate was still pending at the kill: checkpoint 6
    # cannot reproduce it, checkpoint 4 can (it re-runs step 4)
    assert js.resume_step([0, 2, 4, 6]) == 4
    assert js.reestimations == 1
    assert [s for s, _, _ in js.epochs_below(4)] == [2]


def test_resume_refuses_drifted_config():
    js = _state([{"t": "start", "steps": 8, "check_every": 1,
                  "async_window": 2, "ckpt_every": 4, "reestimate_every": 0,
                  "seed": 0, "drift_alpha": 0.125}])
    good = {"steps": 8, "check_every": 1, "async_window": 2, "ckpt_every": 4,
            "reestimate_every": 0, "seed": 0, "drift_alpha": 0.125}
    assert js.config_mismatches(good) == []
    drifted = dict(good, check_every=2, seed=1)
    mism = js.config_mismatches(drifted)
    assert len(mism) == 2 and any("check_every" in m for m in mism)


def test_flagged_below_collects_failed_verdicts():
    thr = Thresholds(eps=2.0 ** -24)
    bad = report_from_errs([(C.KIND_ACT, "m1/output", None)], [1.0], thr)
    events = [{"t": "verdict", "step": 1, "report": report_to_payload(bad)},
              {"t": "verdict", "step": 2, "report": None}]
    js = _state(events)
    assert js.flagged_below(5) == [1]
    assert js.flagged_below(1) == []


# ---------------------------------------------------------------------------
# fault-spec refusal path (make_injector + CLI)
# ---------------------------------------------------------------------------

def test_make_injector_refusals():
    with pytest.raises(ValueError, match="unknown fault"):
        make_injector("segfault_everything", 3)
    with pytest.raises(ValueError, match="needs --fault-step"):
        make_injector("crash", None)
    with pytest.raises(ValueError, match=">= 0"):
        make_injector("crash", -1)
    with pytest.raises(ValueError, match="without --fault"):
        make_injector(None, 3)
    assert make_injector(None, None) is None
    inj = make_injector("nan_step", 2)
    assert inj.spec.fault_id == "nan_step" and inj.step == 2


@pytest.mark.parametrize("argv", [
    ["--fault", "segfault_everything", "--fault-step", "1"],
    ["--fault", "crash"],
    ["--fault", "crash", "--fault-step", "-1"],
    ["--fault-step", "3"],
    ["--resume"],                       # resume without --work-dir
])
def test_cli_refuses_malformed_fault_specs(argv):
    from repro.launch import supervise as cli
    with pytest.raises(SystemExit) as ei:
        cli.main(argv)
    # argparse uses exit code 2; our refusals carry the message itself —
    # either way the run never starts
    assert ei.value.code not in (0, None)


def test_every_fault_names_a_known_site():
    sites = {"step_start", "check_future", "cand_trace", "post_spill",
             "post_ckpt", "spill_writer"}
    for spec in FAULTS.values():
        assert spec.site in sites
        assert spec.recovery        # tolerating it is part of the contract


def test_injector_fires_exactly_at_step_unless_sticky():
    inj = make_injector("crash", 3, crash_handler=_boom)
    inj.step_start(2)
    assert inj.fired == 0
    with pytest.raises(Boom):
        inj.step_start(3)
    sticky = make_injector("hang_check", 2)
    assert sticky.check_future(1, "dev") == "dev"
    hung = sticky.check_future(4, "dev")      # sticky: every step >= 2
    assert not hung.is_ready()


# ---------------------------------------------------------------------------
# background writer: loud death, restart
# ---------------------------------------------------------------------------

def _wait_for(pred, timeout_s=5.0):
    t0 = time.monotonic()
    while not pred():
        if time.monotonic() - t0 > timeout_s:
            raise AssertionError("condition not reached in time")
        time.sleep(0.01)


def test_background_writer_surfaces_error_and_survives():
    w = BackgroundWriter("test-writer")
    w.submit(lambda: (_ for _ in ()).throw(ValueError("disk full")))
    with pytest.raises(ValueError, match="disk full"):
        w.flush()
    assert w.alive                  # a failing WRITE does not kill the worker
    ran = []
    w.submit(lambda: ran.append(1))
    w.flush()
    assert ran == [1] and w.failed_writes == 1


def test_background_writer_death_flush_does_not_hang():
    w = BackgroundWriter("test-writer", queue_max=4)
    w.submit(lambda: (_ for _ in ()).throw(WriterDeath("killed")))
    _wait_for(lambda: not w.alive)
    # writes stranded behind the corpse: flush must drain, not deadlock
    w._queue.put(lambda: None)
    with pytest.raises(WriterDeath, match="killed"):
        w.flush()
    ran = []
    w.submit(lambda: ran.append(1))     # ensure() restarts the worker
    w.flush()
    assert w.alive and ran == [1]


def test_trace_ring_reraises_writer_death_on_next_put_and_restarts(tmp_path):
    ring = TraceRing(window=1, spill_dir=str(tmp_path), background=True)
    ring.fault_hook = lambda step: (WriterDeath(f"died spilling {step}")
                                    if step == 0 else None)
    ring.put(0, _mk_trace(0.0), _mk_trace(0.0))
    ring.put(1, _mk_trace(1.0), _mk_trace(1.0))   # evicts 0 -> writer dies
    _wait_for(lambda: ring._writer._error is not None)
    with pytest.raises(WriterDeath):
        ring.put(2, _mk_trace(2.0), _mk_trace(2.0))
    # the worker restarted: later evictions spill normally
    ring.put(3, _mk_trace(3.0), _mk_trace(3.0))
    ring.flush()
    assert 0 not in ring.on_disk and ring.drop_count >= 1
    assert set(ring.on_disk) >= {1, 2}


def test_trace_ring_reraises_writer_death_on_get(tmp_path):
    ring = TraceRing(window=1, spill_dir=str(tmp_path), background=True)
    ring.fault_hook = lambda step: WriterDeath("sick disk")
    ring.put(0, _mk_trace(0.0), _mk_trace(0.0))
    ring.put(1, _mk_trace(1.0), _mk_trace(1.0))
    _wait_for(lambda: ring._writer._error is not None)
    with pytest.raises(WriterDeath, match="sick disk"):
        ring.get(1)


def test_trace_ring_corrupt_spill_detected_at_get(tmp_path):
    ring = TraceRing(window=1, spill_dir=str(tmp_path))
    ring.put(0, _mk_trace(0.0), _mk_trace(0.0))
    ring.put(1, _mk_trace(1.0), _mk_trace(1.0))   # spills 0 synchronously
    root = os.path.join(str(tmp_path), "step_000000", "cand")
    shard = os.path.join(root, sorted(
        f for f in os.listdir(root) if f.startswith("shard_"))[0])
    with open(shard, "r+b") as f:
        f.seek(os.path.getsize(shard) // 2)
        f.write(b"\xff\xff\xff\xff")
    with pytest.raises(KeyError, match="corrupt"):
        ring.get(0)
    assert ring.corrupt_count == 1


def test_trace_ring_rescan_rebuilds_spill_index(tmp_path):
    ring = TraceRing(window=1, spill_dir=str(tmp_path))
    for k in range(3):
        ring.put(k, _mk_trace(float(k)), _mk_trace(float(k)))
    spilled = ring.on_disk
    assert spilled                      # steps evicted past the window
    fresh = TraceRing(window=1, spill_dir=str(tmp_path))
    assert fresh.rescan() == spilled    # a new incarnation can address them
    ref, cand = fresh.get(spilled[0])
    assert ref.loss == float(spilled[0])


# ---------------------------------------------------------------------------
# watchdog ladder + degradation policy
# ---------------------------------------------------------------------------

def test_watchdog_returns_value_and_propagates_errors():
    wd = Watchdog(timeout_s=5.0, retries=0)
    assert wd.wait(lambda: 42, "quick", 0) == 42
    with pytest.raises(ValueError, match="inner"):
        wd.wait(lambda: (_ for _ in ()).throw(ValueError("inner")), "err", 1)
    assert wd.timeouts == 0


def test_watchdog_retry_then_timeout():
    wd = Watchdog(timeout_s=0.05, retries=1)
    with pytest.raises(CheckTimeout, match="step 7"):
        wd.wait(lambda: time.sleep(30), "check transfer", 7)
    assert wd.timeouts == 2
    assert [e.kind for e in wd.events] == ["retry", "timeout"]


def test_watchdog_events_reach_on_event():
    seen = []
    wd = Watchdog(timeout_s=0.05, retries=0, on_event=seen.append)
    with pytest.raises(CheckTimeout):
        wd.wait(lambda: time.sleep(30), "x", 3)
    assert [e.kind for e in seen] == ["timeout"] and seen[0].step == 3


def test_wait_ready_passthrough_and_boundary_timeout():
    plain = object()
    assert wait_ready(plain, 0.01, "x") is plain        # no is_ready probe
    assert wait_ready(None, None, "x") is None          # no deadline

    class NeverReady:
        def is_ready(self):
            return False

    with pytest.raises(BoundaryTimeout, match="act 0->1"):
        wait_ready(NeverReady(), 0.05, "boundary act 0->1 mb0")

    class ReadyLater:
        def __init__(self):
            self.t0 = time.monotonic()

        def is_ready(self):
            return time.monotonic() - self.t0 > 0.02

    v = ReadyLater()
    assert wait_ready(v, 5.0, "x") is v


def test_degradation_controller_doubles_caps_and_recovers():
    events = []
    dc = DegradationController(check_every=2, degrade_after=2, max_mult=4,
                               on_event=events.append)
    dc.note(0, True)
    assert dc.effective_check_every == 2       # one stall is not a trend
    dc.note(2, True)
    assert dc.effective_check_every == 4 and dc.degraded
    dc.note(4, True)
    dc.note(6, True)
    assert dc.effective_check_every == 8       # capped at max_mult
    dc.note(8, True)
    dc.note(10, True)
    assert dc.effective_check_every == 8
    dc.note(12, False)
    dc.note(14, False)
    assert dc.effective_check_every == 4       # one rung back per streak
    dc.note(16, False)
    dc.note(18, False)
    assert dc.effective_check_every == 2 and not dc.degraded
    assert [e.kind for e in events] == ["degrade", "degrade", "recover",
                                       "recover"]


# ---------------------------------------------------------------------------
# LOUD classification
# ---------------------------------------------------------------------------

def test_nan_rel_err_is_loud_failure_not_silent_pass():
    thr = Thresholds(eps=2.0 ** -24)
    entries = [(C.KIND_ACT, "m1/input", None),
               (C.KIND_ACT, "m1/output", None)]
    rep = report_from_errs(entries, [1e-9, float("nan")], thr)
    assert not rep.passed                      # NaN > thr is False — the trap
    loud = rep.loud
    assert [r.name for r in loud] == ["m1/output"]
    assert "LOUD" in loud[0].note and "LOUD" in rep.summary()
    clean = report_from_errs(entries, [1e-9, 1e-9], thr)
    assert clean.passed and not clean.loud


# ---------------------------------------------------------------------------
# checksummed payloads
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("container", ["npz", "raw"])
def test_corrupt_checkpoint_raises_checksum_error(tmp_path, container):
    path = str(tmp_path / container)
    tree = {"w": np.arange(64, dtype=np.float32),
            "b": np.ones(8, dtype=np.float32)}
    save_checkpoint(path, tree, step=3, container=container)
    named, step, _ = load_checkpoint_named(path)
    assert step == 3 and np.array_equal(named["w"], tree["w"])
    shard = os.path.join(path, sorted(
        f for f in os.listdir(path) if f.startswith("shard_"))[0])
    with open(shard, "r+b") as f:
        f.seek(os.path.getsize(shard) // 2)
        f.write(b"\x5a\x5a\x5a\x5a")
    with pytest.raises(ChecksumError):
        load_checkpoint_named(path)


def test_pre_checksum_manifest_loads_unchecked(tmp_path):
    path = str(tmp_path / "old")
    tree = {"w": np.arange(16, dtype=np.float32)}
    save_checkpoint(path, tree)
    mpath = os.path.join(path, MANIFEST)
    with open(mpath) as f:
        man = json.load(f)
    for entry in man["leaves"].values():
        for piece in entry["pieces"]:
            piece.pop("crc", None)      # a manifest written before checksums
    with open(mpath, "w") as f:
        json.dump(man, f)
    named, _, _ = load_checkpoint_named(path)
    assert np.array_equal(named["w"], tree["w"])


def test_checkpoint_keeper_background_writer_verify_discard(tmp_path):
    keeper = CheckpointKeeper(str(tmp_path), background=True)
    state = ({"w": np.ones(8, np.float32)}, {"m": np.zeros(8, np.float32)})
    for k in (0, 2, 4):
        keeper.save(k, state, state)
    keeper.flush()
    assert keeper.steps == [0, 2, 4]
    assert all(keeper.verify(s) for s in keeper.steps)
    # rot checkpoint 2 on disk: verify is the durable-checkpoint gate
    root = keeper._dir(2)
    shard = os.path.join(root, sorted(
        f for f in os.listdir(root) if f.startswith("shard_"))[0])
    with open(shard, "r+b") as f:
        f.truncate(os.path.getsize(shard) // 2)
    assert not keeper.verify(2)
    keeper.discard(2)
    assert keeper.steps == [0, 4]
    fresh = CheckpointKeeper(str(tmp_path))
    assert fresh.rescan() == [0, 4]


# ---------------------------------------------------------------------------
# slow lane: the real supervised loop under faults
# ---------------------------------------------------------------------------

def _require_devices(n=4):
    import jax
    if len(jax.devices()) < n:
        pytest.skip(f"only {len(jax.devices())} in-process device(s): jax "
                    f"initialized before XLA_FLAGS could force 8")


def _fresh(work_dir, *, bugs=frozenset(), zero1=False, fault=None,
           **overrides):
    import dataclasses as dc

    import jax
    from repro.configs.base import get_config
    from repro.models.model import Model
    from repro.optim.adamw import AdamW
    from repro.parallel.api import ParallelConfig
    from repro.supervise import Supervisor, SuperviseConfig
    cfg = dc.replace(get_config("tinyllama-1.1b").reduced(),
                     tie_embeddings=True)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    kw = dict(steps=8, check_every=1, async_window=2, ckpt_every=2,
              work_dir=str(work_dir), seed=0)
    kw.update(overrides)
    pcfg = ParallelConfig(dp=2, tp=2, zero1=zero1, bugs=frozenset(bugs))
    return Supervisor(model, cfg, pcfg, AdamW(lr=1e-3), params=params,
                      scfg=SuperviseConfig(**kw), batch_size=4, seq_len=32,
                      fault=fault)


def _record_sets(res):
    return {k: None if rep is None
            else [(r.kind, r.name, r.rel_err, r.flagged)
                  for r in rep.records]
            for k, rep in res.checks.items()}


_BASELINE = {}


def _baseline(tmp_path_factory):
    if "res" not in _BASELINE:
        wd = tmp_path_factory.mktemp("baseline")
        sup = _fresh(wd, reestimate_every=3, stop_on_flag=False)
        _BASELINE["res"] = sup.run()
    return _BASELINE["res"]


@pytest.mark.slow
@settings(max_examples=2, deadline=None)
@given(crash_step=st.integers(min_value=2, max_value=6))
def test_crash_resume_converges_with_uninterrupted(tmp_path_factory,
                                                   crash_step):
    """SIGKILL-equivalent abort at a property-chosen step, then resume:
    the resumed run must converge to the uninterrupted run's verdicts —
    same checked steps, bit-equal rel-errs, same threshold epochs."""
    _require_devices()
    base = _baseline(tmp_path_factory)
    wd = tmp_path_factory.mktemp(f"crash{crash_step}")
    sup = _fresh(wd, reestimate_every=3, stop_on_flag=False,
                 fault=make_injector("crash", crash_step,
                                     crash_handler=_boom))
    with pytest.raises(Boom):
        sup.run()
    res = _fresh(wd, reestimate_every=3, stop_on_flag=False).resume()
    assert res.resumed_from is not None and res.resumed_from <= crash_step
    assert res.steps_run == base.steps_run
    assert set(res.checks) == set(base.checks)
    assert _record_sets(res) == _record_sets(base)
    assert res.reestimations == base.reestimations
    assert res.flagged == base.flagged


@pytest.mark.slow
def test_resume_refuses_drifted_config_end_to_end(tmp_path):
    _require_devices()
    j = Journal(journal_path(str(tmp_path)))
    cfg = {"steps": 8, "check_every": 1, "async_window": 2, "ckpt_every": 2,
           "reestimate_every": 0, "seed": 0, "drift_alpha": 0.125}
    j.append("start", **dict(cfg, check_every=2))
    j.close()
    with pytest.raises(ValueError, match="drifted config"):
        _fresh(tmp_path).resume()


@pytest.mark.slow
def test_flagged_run_resumes_to_same_first_bad_step(tmp_path_factory):
    """A buggy run killed mid-flight must resume to the same verdict:
    flagged, same first online flag, same bisected first-bad-step, same
    localized module."""
    _require_devices()
    kw = dict(bugs={"zero_skipped_update"}, zero1=True, steps=8)
    wd0 = tmp_path_factory.mktemp("flag-base")
    base = _fresh(wd0, **kw).run()
    assert base.flagged and base.localized_module == "optimizer"

    wd = tmp_path_factory.mktemp("flag-crash")
    sup = _fresh(wd, fault=make_injector("crash", 2, crash_handler=_boom),
                 **kw)
    try:
        sup.run()
    except Boom:
        pass        # stop_on_flag may resolve the flag before step 2 fires
    res = _fresh(wd, **kw).resume()
    assert res.flagged
    assert res.first_flagged_step == base.first_flagged_step
    assert res.first_bad_step == base.first_bad_step
    assert res.localized_module == base.localized_module


@pytest.mark.slow
@pytest.mark.parametrize("fault_id", sorted(FAULTS))
def test_every_fault_is_injected_detected_and_recovered(fault_id,
                                                        tmp_path):
    """The fault matrix: each registered fault fires inside a real
    supervised run and the run shows the registry's promised recovery."""
    _require_devices()
    wd = str(tmp_path)

    if fault_id == "crash":
        sup = _fresh(wd, steps=6, fault=make_injector(
            "crash", 3, crash_handler=_boom))
        with pytest.raises(Boom):
            sup.run()
        assert sup.fault.fired == 1
        assert any(e["t"] == "start"
                   for e in Journal.read(journal_path(wd)))
        res = _fresh(wd, steps=6).resume()
        assert res.steps_run == 6 and res.passed
        assert res.resumed_from is not None

    elif fault_id == "hang_check":
        sup = _fresh(wd, steps=8, stop_on_flag=False,
                     watchdog_timeout_s=0.3, watchdog_retries=0,
                     degrade_after=2,
                     fault=make_injector("hang_check", 2))
        res = sup.run()
        assert res.steps_run == 8          # training never stalled
        assert res.checks_rescued > 0      # sync fallback from the ring
        assert res.degradations            # saturation degraded to sampling
        assert res.degraded_check_every and res.degraded_check_every > 1
        assert res.passed

    elif fault_id == "nan_step":
        sup = _fresh(wd, steps=6, fault=make_injector("nan_step", 2))
        res = sup.run()
        assert 2 in res.loud_steps         # LOUD, not a threshold question
        assert res.flagged and res.first_bad_step == 2
        assert "LOUD" in res.summary()

    elif fault_id == "corrupt_spill":
        sup = _fresh(wd, steps=8, stop_on_flag=False,
                     fault=make_injector("corrupt_spill", 1))
        res = sup.run()
        assert res.steps_run == 8
        with pytest.raises(KeyError, match="corrupt"):
            sup.ring.get(1)
        assert sup.ring.corrupt_count == 1

    elif fault_id == "truncate_ckpt":
        sup = _fresh(wd, steps=6, stop_on_flag=False,
                     fault=make_injector("truncate_ckpt", 2))
        res = sup.run()
        assert res.steps_run == 6
        assert sup.keeper.verify(0) and not sup.keeper.verify(2)
        # the bisection probe answers "diverged" for the rotted
        # checkpoint and discards it: the search retreats, never builds
        # a verdict on corrupt state
        assert sup._params_diverged(2) is True
        assert 2 not in sup.keeper.steps
        assert any("corrupt checkpoint" in e.detail
                   for e in sup.watchdog.events)

    elif fault_id == "dead_spill_writer":
        sup = _fresh(wd, steps=8, stop_on_flag=False,
                     fault=make_injector("dead_spill_writer", 1))
        res = sup.run()
        assert res.steps_run == 8          # spill death never stops training
        assert any("spill writer" in e for e in res.watchdog_events)
        assert sup.ring.drop_count >= 1    # the poisoned write was dropped
        assert sup.ring.spill_count >= 1   # the restarted worker kept going

    else:                                   # a new fault without a test
        pytest.fail(f"no matrix case for registered fault {fault_id!r}")


@pytest.mark.slow
def test_truncated_ckpt_replay_falls_back_to_earlier_checkpoint(tmp_path):
    _require_devices()
    sup = _fresh(str(tmp_path), steps=6, stop_on_flag=False,
                 fault=make_injector("truncate_ckpt", 4))
    res = sup.run()
    assert res.steps_run == 6 and not sup.keeper.verify(4)
    n_events = len(sup.watchdog.events)
    # replay anchored at the rotted checkpoint: retreats to an earlier
    # durable one instead of restoring garbage; the clean run stays clean
    assert sup._replay(4, 5) is None
    assert 4 not in sup.keeper.steps
    assert any("corrupt checkpoint at replay" in e.detail
               for e in sup.watchdog.events[n_events:])


@pytest.mark.slow
def test_cli_sigkill_then_resume(tmp_path):
    """The real thing: a true SIGKILL through the CLI fault harness, then
    ``--resume`` completes the run from the journal."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    wd = str(tmp_path / "run")
    common = [sys.executable, "-m", "repro.launch.supervise", "--reduced",
              "--steps", "6", "--ckpt-every", "2", "--work-dir", wd]
    out = subprocess.run(common + ["--fault", "crash", "--fault-step", "4"],
                         capture_output=True, text=True, timeout=2400,
                         env=env, cwd=ROOT)
    assert out.returncode == -signal.SIGKILL, out.stdout + out.stderr
    assert os.path.exists(journal_path(wd))
    out2 = subprocess.run(common + ["--resume"], capture_output=True,
                          text=True, timeout=2400, env=env, cwd=ROOT)
    assert out2.returncode == 0, out2.stdout + "\n" + out2.stderr
    assert "resumed from journaled checkpoint" in out2.stdout
    assert "PASS" in out2.stdout
