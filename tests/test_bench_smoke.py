"""Bench rot guard: the supervisor bench must stay runnable end to end.

BENCH_*.json rows are tracked artifacts; nothing would notice a bench
worker crashing until the next regeneration.  This smoke test runs the
real harness (``benchmarks/run.py --only supervisor --smoke``) with
tiny step counts: every supervised configuration in the worker executes,
every row is emitted, and the tracked JSON is left untouched.
"""
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.mark.slow
def test_supervisor_bench_smoke_emits_every_row_and_touches_no_json():
    json_path = os.path.join(ROOT, "BENCH_supervisor.json")
    before = open(json_path).read() if os.path.exists(json_path) else None
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run(
        [sys.executable, "-m", "benchmarks.run", "--only", "supervisor",
         "--smoke"],
        capture_output=True, text=True, timeout=3000, env=env, cwd=ROOT)
    assert out.returncode == 0, out.stdout + "\n" + out.stderr
    for row in ("supervisor/plain", "supervisor/nocheck", "supervisor/sync",
                "supervisor/async2", "supervisor/async2_spill",
                "supervisor/journal",
                "supervisor/pp2_async2", "supervisor/pp1f1b_async2",
                "supervisor/fp8_tile128_async2", "supervisor/reest_async2"):
        assert row in out.stdout, (row, out.stdout)
    assert "# all benchmarks completed" in out.stdout
    after = open(json_path).read() if os.path.exists(json_path) else None
    assert after == before          # smoke never rewrites tracked rows
