"""Minimal stand-in for ``hypothesis`` when it isn't installed.

This container has no route to PyPI, so the property-test modules import
through::

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        from _hypothesis_compat import given, settings, strategies as st

The shim runs each ``@given`` test over a small deterministic example set:
the strategy's boundary values first, then seeded pseudo-random draws up to
``max_examples``.  It covers exactly the hypothesis surface this repo uses
(``integers``, ``floats``, ``sampled_from``, ``booleans``; ``settings`` with
``max_examples``/``deadline``) — no shrinking, no database, no phases.
"""
from __future__ import annotations

import functools
import inspect
import math
import random


class _Strategy:
    def __init__(self, sample, edges=()):
        self._sample = sample
        self.edges = list(edges)


class strategies:
    @staticmethod
    def integers(min_value, max_value):
        return _Strategy(lambda rng: rng.randint(min_value, max_value),
                         edges=[min_value, max_value])

    @staticmethod
    def floats(min_value, max_value):
        def sample(rng):
            if min_value > 0 and max_value / min_value > 100:
                # span crosses decades -> log-uniform, like hypothesis tends
                # to explore magnitudes
                return math.exp(rng.uniform(math.log(min_value),
                                            math.log(max_value)))
            return rng.uniform(min_value, max_value)
        return _Strategy(sample, edges=[min_value, max_value])

    @staticmethod
    def sampled_from(elements):
        elements = list(elements)
        return _Strategy(lambda rng: rng.choice(elements), edges=elements)

    @staticmethod
    def booleans():
        return _Strategy(lambda rng: rng.random() < 0.5,
                         edges=[False, True])


st = strategies


def settings(max_examples: int = 10, deadline=None, **_ignored):
    def deco(fn):
        fn._compat_settings = {"max_examples": max_examples}
        return fn
    return deco


def given(**strats):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            # read settings at CALL time: @settings may sit above OR below
            # @given (both orders are valid hypothesis), i.e. the attribute
            # may land on `fn` or on `wrapper` itself
            max_examples = (getattr(wrapper, "_compat_settings", None)
                            or getattr(fn, "_compat_settings", None)
                            or {}).get("max_examples", 10)
            rng = random.Random(0x7735ACE)
            names = list(strats)
            examples = []
            n_edges = max((len(strats[n].edges) for n in names), default=0)
            for i in range(n_edges):
                examples.append({
                    n: (strats[n].edges[i % len(strats[n].edges)]
                        if strats[n].edges else strats[n]._sample(rng))
                    for n in names})
            while len(examples) < max_examples:
                examples.append({n: strats[n]._sample(rng) for n in names})
            for ex in examples[:max_examples]:
                fn(*args, **ex, **kwargs)

        # hide the strategy-filled parameters from pytest's fixture
        # resolution (hypothesis does the same via its own wrapper)
        params = [p for p in inspect.signature(fn).parameters.values()
                  if p.name not in strats]
        wrapper.__signature__ = inspect.Signature(params)
        return wrapper
    return deco
