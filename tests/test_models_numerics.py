"""Model-zoo numerical invariants: decode==prefill, chunked==recurrent,
blockwise==naive attention, scan==unrolled."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                       # no PyPI route in CI image
    from _hypothesis_compat import given, settings, strategies as st

from repro.configs.base import get_config, list_configs
from repro.data.synthetic import make_batch
from repro.models.attention import attention_blockwise, attention_ref
from repro.models.model import Model
from repro.models.ssm import lin_attn_chunked, lin_attn_recurrent

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("name", [n for n in list_configs()
                                  if get_config(n).is_decoder])
def test_decode_matches_forward(name):
    """Stepping the decode path over a prompt must reproduce the teacher-
    forced forward logits (KV caches / SSM states are exact)."""
    cfg = get_config(name).reduced()
    m = Model(cfg)
    p = m.init(jax.random.PRNGKey(3))
    b = make_batch(cfg, 1, 16)
    # vlm decode consumes text tokens only; compare against a text-only
    # forward (the image prefix is a prefill concern)
    b.pop("image_embeds", None)
    T = b["tokens"].shape[1]
    h, _ = m.forward(p, b)
    want = m.unembed(p, h)
    cache = m.init_cache(1, T)
    outs = []
    dec = jax.jit(m.decode_step)
    for t in range(T):
        lg, cache = dec(p, cache, b["tokens"][:, t:t + 1], jnp.int32(t))
        outs.append(lg[:, 0])
    got = jnp.stack(outs, 1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-4)


@given(sq=st.sampled_from([128, 256]), blk=st.sampled_from([32, 64, 128]),
       mode=st.sampled_from(["causal", "swa", "bidirectional"]))
@settings(max_examples=12, deadline=None)
def test_blockwise_attention_property(sq, blk, mode):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, sq, 4, 32))
    k = jax.random.normal(ks[1], (1, sq, 2, 32))
    v = jax.random.normal(ks[2], (1, sq, 2, 32))
    a = attention_ref(q, k, v, mode=mode, window=48)
    b = attention_blockwise(q, k, v, mode=mode, window=48, q_block=blk,
                            kv_block=blk)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


@given(chunk=st.sampled_from([8, 16, 32]), scalar=st.booleans(),
       rwkv=st.booleans())
@settings(max_examples=12, deadline=None)
def test_lin_attn_chunked_equals_recurrent(chunk, scalar, rwkv):
    B, S, H, dk, dv = 1, 64, 2, 8, 8
    ks = jax.random.split(KEY, 4)
    q = jax.random.normal(ks[0], (B, S, H, dk))
    k = jax.random.normal(ks[1], (B, S, H, dk))
    v = jax.random.normal(ks[2], (B, S, H, dv))
    if scalar:
        lw = -jax.nn.softplus(jax.random.normal(ks[3], (B, S, H, 1)))
    else:
        lw = -0.05 * jax.nn.sigmoid(jax.random.normal(ks[3], (B, S, H, dk)))
    u = 0.4 * jnp.ones((H, dk)) if rwkv else None
    y1, s1 = lin_attn_chunked(q, k, v, lw, chunk=chunk, u=u)
    y2, s2 = lin_attn_recurrent(q, k, v, lw, u=u)
    np.testing.assert_allclose(np.asarray(y1, np.float32),
                               np.asarray(y2, np.float32), atol=1e-3)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=1e-3)


def test_scan_equals_unrolled_layers():
    cfg = dataclasses.replace(get_config("tinyllama-1.1b").reduced(),
                              n_layers=4)
    m_un = Model(cfg)
    m_sc = Model(dataclasses.replace(cfg, scan_layers=True))
    p = m_un.init(jax.random.PRNGKey(1))
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *p["layers"])
    p2 = dict(p)
    p2["layers"] = stacked
    b = make_batch(cfg, 2, 32)
    l1, _ = m_un.loss(p, b)
    l2, _ = m_sc.loss(p2, b)
    assert float(jnp.abs(l1 - l2)) < 1e-6


def test_swa_ring_buffer_matches_full_cache():
    """Mixtral-style sliding-window ring buffer == full cache + window mask."""
    cfg = dataclasses.replace(get_config("mixtral-8x7b").reduced(),
                              n_layers=2, window=8)
    m = Model(cfg)
    p = m.init(jax.random.PRNGKey(2))
    b = make_batch(cfg, 1, 24)
    h, _ = m.forward(p, b)
    want = m.unembed(p, h)
    cache = m.init_cache(1, 24)   # ring buffer of size window=8
    # ring cache is bounded by the window
    kshape = jax.tree.leaves(cache)[0].shape
    assert 8 in kshape
    dec = jax.jit(m.decode_step)
    outs = []
    for t in range(24):
        lg, cache = dec(p, cache, b["tokens"][:, t:t + 1], jnp.int32(t))
        outs.append(lg[:, 0])
    got = jnp.stack(outs, 1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-4)


def test_mla_cache_is_compressed():
    """DeepSeek MLA decode cache stores kv_lora + rope dims, not full K/V."""
    cfg = get_config("deepseek-v2-236b")
    m = Model(cfg.reduced())
    cache = jax.eval_shape(lambda: m.init_cache(2, 64))
    leaves = {tuple(x.shape[-1:])[0] for x in jax.tree.leaves(cache)}
    rc = m.cfg.mla
    assert rc.kv_lora_rank in leaves and rc.qk_rope_dim in leaves
    full_dim = m.cfg.n_heads * (rc.qk_nope_dim + rc.v_head_dim)
    assert all(d < full_dim for d in leaves)
