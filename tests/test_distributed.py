"""Distributed TTrace integration tests (8 forced host devices, run in
subprocess workers via ``conftest.run_in_worker`` — isolation keeps each
case's jit/tap caches and device state independent of the main process,
which itself runs with 8 forced devices since the 1F1B engine landed)."""
import os

import pytest

def _run(code: str, devices: int = 8, timeout: int = 2400) -> str:
    from conftest import run_in_worker
    return run_in_worker(code, devices=devices, timeout=timeout)


PREAMBLE = """
import dataclasses, jax
from repro.configs.base import get_config, MoEConfig
from repro.models.model import Model
from repro.data.synthetic import make_batch
from repro.optim.adamw import AdamW
from repro.core.harness import make_model_runner, ttrace_check
from repro.parallel.api import ParallelConfig, make_candidate_runner

cfg = dataclasses.replace(get_config("gpt-paper").reduced(),
                          n_layers=2, vocab=512, tie_embeddings=True)
m = Model(cfg)
params = m.init(jax.random.PRNGKey(0))
opt = AdamW(lr=1e-3); st = opt.init(params)
batch = make_batch(cfg, 4, 32)
ref = make_model_runner(m, params, opt, st)
"""


@pytest.mark.slow
def test_clean_parallel_matrix_passes():
    out = _run(PREAMBLE + """
for pc in [ParallelConfig(dp=2, tp=2),
           ParallelConfig(dp=2, tp=2, sp=True),
           ParallelConfig(dp=2, cp=2, tp=2, sp=True),
           ParallelConfig(dp=2, tp=2, zero1=True)]:
    cand = make_candidate_runner(cfg, pc, params, opt, st)
    res = ttrace_check(ref, cand, batch, localize=False)
    print(pc.features, "passed:", res.passed)
    assert res.passed, res.report.summary()
print("ALL_CLEAN_PASS")
""")
    assert "ALL_CLEAN_PASS" in out


@pytest.mark.slow
@pytest.mark.parametrize("bug,req", [
    ("tp_wrong_embedding_mask", ""),
    ("sp_layernorm_not_synced", "sp"),
    ("cp_wrong_attention_grad", "cp"),
])
def test_injected_bug_detected_and_localized(bug, req):
    out = _run(PREAMBLE + f"""
import fnmatch
from repro.bugs.registry import BUGS
spec = BUGS["{bug}"]
pc = ParallelConfig(dp=2, cp=2 if "cp" in spec.requires else 1, tp=2,
                    sp="sp" in spec.requires,
                    zero1="zero1" in spec.requires,
                    bugs=frozenset(["{bug}"]))
cand = make_candidate_runner(cfg, pc, params, opt, st)
res = ttrace_check(ref, cand, batch, localize=True)
assert not res.passed, "bug not detected"
loc = res.localized_module or "-"
assert fnmatch.fnmatchcase(loc, spec.expected_module), (loc,
                                                        spec.expected_module)
print("DETECTED_AND_LOCALIZED", loc)
""")
    assert "DETECTED_AND_LOCALIZED" in out


@pytest.mark.slow
def test_merge_jax_array_layout_verification():
    """merger.merge_jax_array reconstructs a sharded jax.Array and verifies
    its device layout against the user annotation."""
    out = _run("""
import jax, numpy as np
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from repro.core.annotations import ShardSpec
from repro.core.merger import merge_jax_array

mesh = Mesh(np.array(jax.devices()[:4]).reshape(2, 2), ("dp", "tp"))
x = jnp.arange(64.0).reshape(8, 8)
xs = jax.device_put(x, NamedSharding(mesh, P(None, "tp")))
full, rep = merge_jax_array(xs, ShardSpec(tp_dim=1),
                            {"tp": "tp", "dp": "dp"})
assert rep.ok, rep.problems()
np.testing.assert_allclose(full, np.asarray(x))

# wrong annotation (claims dim 0) -> layout mismatch reported
full2, rep2 = merge_jax_array(xs, ShardSpec(tp_dim=0),
                              {"tp": "tp", "dp": "dp"})
assert not rep2.ok and rep2.layout_mismatches
print("MERGE_OK")
""", devices=4)
    assert "MERGE_OK" in out
