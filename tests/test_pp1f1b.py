"""Real multi-device 1F1B pipeline: schedule properties, per-rank trace
merging, and equivalence against the single-device reference.

Hypothesis property suite (ISSUE 4): for arbitrary (L, pp, microbatches) —
every (stage, microbatch) forward and backward executes exactly once, the
backward order is the 1F1B interleave, merged trace names biject onto the
single-device reference trace names, and gradient accumulation equals the
full-batch gradient within threshold.
"""
import dataclasses

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_compat import given, settings, strategies as st

from repro.core.merger import (MergeReport, canonical_stage_name,
                               merge_microbatch_traces)
from repro.parallel.pp1f1b import (schedule_1f1b, stage_op_stream,
                                   stage_tables)


# ---------------------------------------------------------------------------
# schedule properties (pure, no jax)
# ---------------------------------------------------------------------------

@settings(max_examples=80, deadline=None)
@given(pp=st.integers(2, 8), M=st.integers(1, 12))
def test_schedule_every_op_exactly_once_and_dependency_valid(pp, M):
    order = schedule_1f1b(pp, M)
    assert len(order) == pp * 2 * M
    fwd, bwd = set(), set()
    for d, s, m in order:
        if d == "F":
            # forward (s, m) needs forward (s-1, m)'s boundary activation
            assert s == 0 or (s - 1, m) in fwd, (pp, M, order)
            assert (s, m) not in fwd
            fwd.add((s, m))
        else:
            # backward (s, m) needs backward (s+1, m)'s boundary gradient,
            # and its own forward stash
            assert s == pp - 1 or (s + 1, m) in bwd, (pp, M, order)
            assert (s, m) in fwd
            assert (s, m) not in bwd
            bwd.add((s, m))
    assert fwd == bwd == {(s, m) for s in range(pp) for m in range(M)}


@settings(max_examples=80, deadline=None)
@given(pp=st.integers(2, 8), M=st.integers(1, 12))
def test_schedule_per_stage_order_is_the_1f1b_interleave(pp, M):
    """Each stage's subsequence of the global order IS its canonical 1F1B
    stream: warmup forwards, alternating (F, B), cooldown backwards — so
    backwards run strictly in microbatch order and the last stage strictly
    alternates F/B."""
    order = schedule_1f1b(pp, M)
    for s in range(pp):
        ops = [op for op in order if op[1] == s]
        assert ops == stage_op_stream(pp, s, M)
        assert [m for d, _, m in ops if d == "B"] == list(range(M))
    last = [d for d, s, _ in order if s == pp - 1]
    assert last == ["F", "B"] * M


@settings(max_examples=80, deadline=None)
@given(pp=st.integers(2, 8), M=st.integers(1, 12))
def test_schedule_stash_stays_bounded(pp, M):
    """The 1F1B memory property: stage s never stashes more than
    min(M, pp - s) microbatch inputs (warmup depth + the in-flight one)."""
    order = schedule_1f1b(pp, M)
    depth = [0] * pp
    for d, s, m in order:
        depth[s] += 1 if d == "F" else -1
        assert depth[s] <= min(M, pp - s), (pp, M, s)


@settings(max_examples=100, deadline=None)
@given(L=st.integers(1, 48), pp=st.integers(2, 12))
def test_stage_tables_partition_the_flat_renaming(L, pp):
    pp = min(pp, max(L, 2))
    tables = stage_tables(L, pp)
    # concatenated per-stage tables == the flat table; canonical names
    # biject onto 0..L-1 (the reference layer numbering)
    flat = [e for t in tables for e in t]
    assert [e for e, _ in flat] == list(range(L))
    assert sorted(c for _, c in flat) == list(range(L))
    # the buggy division's tables stay collision-free (spill indices)
    bad = stage_tables(L, pp, frozenset(["pp_wrong_stage_division"]))
    canons = [c for t in bad for _, c in t]
    assert len(canons) == len(set(canons))


def test_canonical_stage_name_renames_layers_only():
    table = [(2, 2), (3, 3)]
    assert canonical_stage_name("layers.1.mlp/input", table) == \
        "layers.3.mlp/input"
    assert canonical_stage_name("layers.0.self_attention.linear_qkv.w",
                                table) == \
        "layers.2.self_attention.linear_qkv.w"
    assert canonical_stage_name("embedding/output", table) == \
        "embedding/output"
    with pytest.raises(KeyError):
        canonical_stage_name("layers.5.mlp/input", table)


# ---------------------------------------------------------------------------
# per-rank merge verification (synthetic records, no model)
# ---------------------------------------------------------------------------

def _rec(stage, mb, act=None, ag=None, pg=None):
    from repro.core.collector import Trace
    tr = Trace()
    if act: tr.activations = act
    if ag: tr.act_grads = ag
    if pg: tr.param_grads = pg
    return (stage, mb, tr)


def _tables(L=4, pp=2):
    return stage_tables(L, pp)


def test_merge_concatenates_microbatches_and_canonicalizes():
    x0, x1 = np.ones((2, 3), np.float32), 2 * np.ones((2, 3), np.float32)
    recs = [
        _rec(0, 0, act={"layers.0.mlp/output": x0},
             pg={"layers.1.mlp.down.w": x0}),
        _rec(0, 1, act={"layers.0.mlp/output": x1},
             pg={"layers.1.mlp.down.w": x1}),
        _rec(1, 0, act={"layers.0.mlp/output": x0}),
        _rec(1, 1, act={"layers.0.mlp/output": x1}),
    ]
    merged, rep = merge_microbatch_traces(recs, _tables(), 2)
    assert rep.ok, rep.problems()
    # stage 0 local layers.0 stays layers.0; stage 1 local layers.0 -> 2
    assert set(merged.activations) == {"layers.0.mlp/output",
                                       "layers.2.mlp/output"}
    np.testing.assert_array_equal(
        merged.activations["layers.0.mlp/output"], np.concatenate([x0, x1]))
    # param-grad contributions accumulate across microbatches
    np.testing.assert_array_equal(
        merged.param_grads["layers.1.mlp.down.w"], x0 + x1)
    assert merged.meta["merge_report"] is rep


def test_merge_reports_omission_overlap_and_collision():
    x = np.ones((2, 2), np.float32)
    # omission: stage 0 contributed mb 0 only (of 2)
    _, rep = merge_microbatch_traces(
        [_rec(0, 0, act={"layers.0.mlp/output": x})], _tables(), 2)
    assert not rep.ok and rep.omission == 1
    # overlap: mb 0 contributed twice
    _, rep = merge_microbatch_traces(
        [_rec(0, 0, act={"layers.0.mlp/output": x}),
         _rec(0, 0, act={"layers.0.mlp/output": x})], _tables(), 1)
    assert not rep.ok and rep.overlap == 1
    # out-of-grid record
    _, rep = merge_microbatch_traces([_rec(7, 0, act={"a": x})],
                                     _tables(), 1)
    assert not rep.ok and rep.rank_problems
    # tied params (non-layer names) sum instead of colliding
    merged, rep = merge_microbatch_traces(
        [_rec(0, 0, pg={"embedding.word_embeddings": x}),
         _rec(1, 0, pg={"embedding.word_embeddings": x})], _tables(), 1)
    assert rep.ok
    np.testing.assert_array_equal(
        merged.param_grads["embedding.word_embeddings"], 2 * x)


def test_merge_problems_fail_the_check_report():
    """A coverage violation must fail the differential check even when all
    compared values agree."""
    from repro.core.checker import compare_traces
    from repro.core.collector import Trace
    from repro.core.thresholds import Thresholds
    x = np.ones((2, 2), np.float32)
    ref = Trace()
    ref.activations = {"layers.0.mlp/output": np.concatenate([x, x])}
    merged, rep = merge_microbatch_traces(
        [_rec(0, 0, act={"layers.0.mlp/output": x}),
         _rec(0, 1, act={"layers.0.mlp/output": x}),
         _rec(0, 1, act={"layers.0.mlp/output": x})], _tables(), 2)
    assert not rep.ok
    report = compare_traces(ref, merged, Thresholds(eps=2.0 ** -24))
    assert not report.passed and report.merge_problems


# ---------------------------------------------------------------------------
# engine equivalence vs the single-device reference (needs forced devices)
# ---------------------------------------------------------------------------

def _tiny_cfg(L):
    from repro.configs.base import get_config
    return dataclasses.replace(
        get_config("gpt-paper").reduced(), n_layers=L, d_model=64,
        n_heads=2, n_kv_heads=2, d_head=32, d_ff=128, vocab=128,
        tie_embeddings=True)


def _engine_setup(L, pp, M, bugs=frozenset(), batch_size=4):
    import jax
    from repro.data.synthetic import make_batch
    from repro.models.model import Model
    from repro.parallel.pp1f1b import PP1F1BEngine
    cfg = _tiny_cfg(L)
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, batch_size, 16)
    eng = PP1F1BEngine(m, params, batch, pp, M, bugs)
    return cfg, m, params, batch, eng


@pytest.mark.multidevice
@settings(max_examples=6, deadline=None)
@given(L=st.integers(2, 6), pp=st.integers(2, 4), M=st.sampled_from([1, 2, 4]))
def test_engine_names_biject_and_grads_accumulate_to_full_batch(
        forced_devices, L, pp, M):
    """The merged per-rank trace carries EXACTLY the reference tensor names,
    and microbatch-accumulated gradients equal the full-batch gradient
    within FP-threshold distance."""
    from repro.core.collector import flatten_named, trace_train_step
    from repro.core.relerr_engine import rel_err_np
    cfg, m, params, batch, eng = _engine_setup(L, pp, M)
    tr, grads, rep = eng.collect(params, batch)
    assert rep.ok, rep.problems()
    ref_tr, _, _ = trace_train_step(m, params, batch)
    # name bijection, per section
    assert set(tr.activations) == set(ref_tr.activations)
    assert set(tr.act_grads) == set(ref_tr.act_grads)
    assert set(tr.param_grads) == set(ref_tr.param_grads)
    assert np.isclose(float(tr.loss), ref_tr.loss, rtol=1e-5)
    # gradient accumulation == full-batch gradients within threshold
    g_named = flatten_named(grads)
    for n, g_ref in ref_tr.param_grads.items():
        err = rel_err_np(np.asarray(g_ref), np.asarray(g_named[n]))
        assert err < 1e-4, (n, err, L, pp, M)
    # ... and the merged trace's accumulated param grads agree too
    for n in ref_tr.param_grads:
        err = rel_err_np(np.asarray(ref_tr.param_grads[n]),
                         np.asarray(tr.param_grads[n]))
        assert err < 1e-4, (n, err)


@pytest.mark.multidevice
def test_engine_one_shot_check_clean_and_stale_boundary(forced_devices):
    """ttrace_check over the 1F1B runner: clean passes, the stale-boundary
    schedule bug is flagged at the first layer of stage 1."""
    import jax
    from repro.core.harness import make_model_runner, ttrace_check
    from repro.data.synthetic import make_batch
    from repro.models.model import Model
    from repro.parallel.api import ParallelConfig, make_candidate_runner
    cfg = _tiny_cfg(4)
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, 4, 16)
    ref = make_model_runner(m, params)
    clean = make_candidate_runner(
        cfg, ParallelConfig(pp=2, pp_schedule="1f1b", microbatches=2),
        params)
    res = ttrace_check(ref, clean, batch, localize=False)
    assert res.passed, res.report.summary()
    buggy = make_candidate_runner(
        cfg, ParallelConfig(pp=2, pp_schedule="1f1b", microbatches=2,
                            bugs=frozenset(["pp_stale_boundary"])),
        params)
    res = ttrace_check(ref, buggy, batch, localize=False)
    assert not res.passed
    assert np.isfinite(res.candidate.loss)          # silent, not a crash
    # stage 1 owns layers 2..3: divergence enters at layer 2
    assert (res.report.localized or "").startswith("layers.2")


@pytest.mark.multidevice
def test_microbatch_order_bug_leaves_forward_untouched(forced_devices):
    """pp_microbatch_order corrupts ONLY the backward: merged activations
    (and the loss) are byte-identical to the clean engine — the loss curve
    is blind to it, the gradient trace is not."""
    cfg, m, params, batch, eng = _engine_setup(4, 2, 4)
    tr_clean, g_clean, _ = eng.collect(params, batch)
    _, _, _, _, eng_bug = _engine_setup(4, 2, 4,
                                        frozenset(["pp_microbatch_order"]))
    tr_bug, g_bug, rep = eng_bug.collect(params, batch)
    assert rep.ok
    assert float(tr_clean.loss) == float(tr_bug.loss)
    for n in tr_clean.activations:
        np.testing.assert_array_equal(tr_clean.activations[n],
                                      tr_bug.activations[n])
    from repro.core.collector import flatten_named
    gc, gb = flatten_named(g_clean), flatten_named(g_bug)
    assert any(not np.allclose(np.asarray(gc[n]), np.asarray(gb[n]),
                               rtol=1e-3)
               for n in gc), "backward bug never expressed"


@pytest.mark.multidevice
def test_supervisor_pp1f1b_clean_run_passes(forced_devices, tmp_path):
    import jax
    from repro.models.model import Model
    from repro.optim.adamw import AdamW
    from repro.parallel.api import ParallelConfig
    from repro.supervise import Supervisor, SuperviseConfig
    cfg = _tiny_cfg(4)
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    sup = Supervisor(m, cfg, ParallelConfig(pp=2, pp_schedule="1f1b",
                                            microbatches=2),
                     AdamW(lr=1e-3), params=params,
                     scfg=SuperviseConfig(steps=4, ckpt_every=2,
                                          work_dir=str(tmp_path)),
                     batch_size=4, seq_len=16)
    res = sup.run()
    assert res.passed, res.summary()
    assert sup.candidate.name == "pp1f1b2x2"
    assert sup.pipe.kind_scale >= 2.0    # the microbatch reassociation margin
