"""Per-architecture smoke tests (deliverable f).

Each assigned architecture is instantiated as the REDUCED variant of the same
family (2 layers, d_model<=256, <=4 experts) and runs one forward + one train
step on CPU, asserting output shapes and absence of NaNs.  The FULL configs
are exercised only via the dry-run (ShapeDtypeStruct, no allocation).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config, list_configs, INPUT_SHAPES
from repro.data.synthetic import make_batch, make_decode_inputs
from repro.models.model import Model
from repro.optim.adamw import AdamW

ARCHS = [n for n in list_configs()]


@pytest.fixture(scope="module")
def built():
    cache = {}

    def get(name):
        if name not in cache:
            cfg = get_config(name).reduced()
            m = Model(cfg)
            params = m.init(jax.random.PRNGKey(0))
            cache[name] = (cfg, m, params)
        return cache[name]

    return get


def _finite(tree):
    return all(bool(jnp.all(jnp.isfinite(x.astype(jnp.float32))))
               for x in jax.tree.leaves(tree))


@pytest.mark.parametrize("name", ARCHS)
def test_forward_shapes_and_finite(built, name):
    cfg, m, params = built(name)
    B, S = 2, 32
    batch = make_batch(cfg, B, S)
    h, aux = jax.jit(m.forward)(params, batch)
    S_out = S if cfg.arch_type != "vlm" else S  # vlm: img tokens prepended
    if cfg.arch_type == "vlm":
        S_out = batch["image_embeds"].shape[1] + batch["tokens"].shape[1]
    assert h.shape == (B, S_out, cfg.d_model)
    assert _finite(h), f"{name}: NaN/Inf in forward hidden states"


@pytest.mark.parametrize("name", ARCHS)
def test_one_train_step(built, name):
    cfg, m, params = built(name)
    batch = make_batch(cfg, 2, 32)
    opt = AdamW(lr=1e-3)
    state = opt.init(params)

    @jax.jit
    def step(params, state, batch):
        (loss, met), grads = jax.value_and_grad(m.loss, has_aux=True)(
            params, batch)
        params, state, info = opt.update(params, grads, state)
        return params, state, loss

    p2, s2, loss = step(params, state, batch)
    assert np.isfinite(float(loss)), f"{name}: non-finite loss"
    assert _finite(p2), f"{name}: NaN/Inf in updated params"
    # the step must actually change the parameters
    delta = sum(float(jnp.sum(jnp.abs(a.astype(jnp.float32)
                                      - b.astype(jnp.float32))))
                for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)))
    assert delta > 0.0, f"{name}: optimizer did not update parameters"


@pytest.mark.parametrize("name", [n for n in ARCHS
                                  if get_config(n).is_decoder])
def test_decode_step(built, name):
    cfg, m, params = built(name)
    B, S = 2, 32
    cache = m.init_cache(B, S)
    toks = make_decode_inputs(cfg, B)["tokens"]
    logits, cache2 = jax.jit(m.decode_step)(params, cache, toks, jnp.int32(3))
    assert logits.shape == (B, 1, cfg.vocab)
    assert _finite(logits), f"{name}: NaN/Inf in decode logits"


def test_all_ten_assigned_archs_present():
    assigned = {
        "deepseek-v2-236b", "rwkv6-7b", "codeqwen1.5-7b", "zamba2-7b",
        "qwen1.5-110b", "mixtral-8x7b", "qwen3-32b", "llava-next-34b",
        "tinyllama-1.1b", "hubert-xlarge",
    }
    assert assigned <= set(list_configs())


def test_full_configs_match_assignment():
    spec = {
        "deepseek-v2-236b": (60, 5120, 128, 128, 1536, 102400),
        "rwkv6-7b": (32, 4096, 64, 64, 14336, 65536),
        "codeqwen1.5-7b": (32, 4096, 32, 32, 13440, 92416),
        "zamba2-7b": (81, 3584, 32, 32, 14336, 32000),
        "qwen1.5-110b": (80, 8192, 64, 8, 49152, 152064),
        "mixtral-8x7b": (32, 4096, 32, 8, 14336, 32000),
        "qwen3-32b": (64, 5120, 64, 8, 25600, 151936),
        "llava-next-34b": (60, 7168, 56, 8, 20480, 64000),
        "tinyllama-1.1b": (22, 2048, 32, 4, 5632, 32000),
        "hubert-xlarge": (48, 1280, 16, 16, 5120, 504),
    }
    for name, (L, d, H, Hkv, dff, V) in spec.items():
        c = get_config(name)
        assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
                c.vocab) == (L, d, H, Hkv, dff, V), name
    assert get_config("deepseek-v2-236b").moe.n_experts == 160
    assert get_config("deepseek-v2-236b").moe.top_k == 6
    assert get_config("deepseek-v2-236b").mla.kv_lora_rank == 512
    assert get_config("mixtral-8x7b").moe.n_experts == 8
    assert get_config("mixtral-8x7b").moe.top_k == 2
    assert get_config("mixtral-8x7b").window == 4096
    assert get_config("zamba2-7b").ssm.d_state == 64
    assert get_config("qwen3-32b").qk_norm
    assert get_config("qwen1.5-110b").qkv_bias
    assert not get_config("hubert-xlarge").causal


def test_shape_skip_rules():
    """long_500k only for sub-quadratic archs; no decode for encoder-only."""
    long = INPUT_SHAPES["long_500k"]
    dec = INPUT_SHAPES["decode_32k"]
    assert get_config("rwkv6-7b").supports_shape(long)[0]
    assert get_config("zamba2-7b").supports_shape(long)[0]
    assert get_config("mixtral-8x7b").supports_shape(long)[0]
    assert not get_config("qwen3-32b").supports_shape(long)[0]
    assert not get_config("hubert-xlarge").supports_shape(dec)[0]
    assert not get_config("hubert-xlarge").supports_shape(long)[0]
