"""Canonical id + PP/VPP layer-index mapping (paper §4.1, Fig 5)."""
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                       # no PyPI route in CI image
    from _hypothesis_compat import given, settings, strategies as st

from repro.core.canonical import (CanonicalId, canonical_layer_index,
                                  canonicalize_module, chunk_layers,
                                  local_layer_index, tap_to_id)


def test_paper_fig5_example():
    # "layer 0 in the 2nd virtual pipeline of the 1st pipeline stage maps to
    # layer 4 in the reference" — pp=2, vpp=2, 8 layers (2 per chunk)
    assert canonical_layer_index(0, pp_rank=0, pp_size=2, vpp_rank=1,
                                 vpp_size=2, n_layers=8) == 4


@given(pp=st.integers(1, 8), vpp=st.integers(1, 4), cpl=st.integers(1, 4))
@settings(max_examples=60, deadline=None)
def test_mapping_is_a_bijection(pp, vpp, cpl):
    n_layers = pp * vpp * cpl
    seen = set()
    for pr in range(pp):
        for vr in range(vpp):
            for li in range(cpl):
                g = canonical_layer_index(li, pr, pp, vr, vpp, n_layers)
                assert 0 <= g < n_layers
                seen.add(g)
                assert local_layer_index(g, pp, vpp, n_layers) == (pr, vr, li)
    assert len(seen) == n_layers


def test_chunk_layers_divisibility():
    with pytest.raises(ValueError):
        chunk_layers(10, 4, 1)
    assert chunk_layers(12, 2, 3) == 2


def test_canonicalize_module_path():
    # local layer 1 on pp_rank 1 of 2 (vpp 1), 8 layers -> global 5
    assert canonicalize_module("layers.1.mlp/output", pp_rank=1, pp_size=2,
                               vpp_rank=0, vpp_size=1, n_layers=8) \
        == "layers.5.mlp/output"
    # no pipeline -> unchanged
    assert canonicalize_module("layers.3.mlp", 0, 1, 0, 1, 8) == "layers.3.mlp"


def test_canonical_id_seed_stable_and_distinct():
    a = CanonicalId(0, 0, "activation", "layers.0.mlp", "input")
    b = CanonicalId(0, 0, "activation", "layers.0.mlp", "output")
    assert a.seed() == CanonicalId(0, 0, "activation", "layers.0.mlp",
                                   "input").seed()
    assert a.seed() != b.seed()


def test_tap_to_id_roundtrip():
    cid = tap_to_id("layers.3.self_attention/input", "activation")
    assert cid.module == "layers.3.self_attention"
    assert cid.role == "input"
