"""Overlap-everything hot loop: the overlapped paths must be bit-identical
to the lockstep paths they replace.

Three layers of pinning:

* ``MergePlan`` execution == ``merge_microbatch_traces`` on randomized
  record sets — clean grids AND the buggy structures (overlap, omission,
  out-of-grid, cross-stage collision, tied params);
* the 1F1B engine's dependency-driven concurrent dispatch == the ordered
  (clock-tick) drive, trace for trace, bit for bit;
* a supervised run with ``overlap=True`` (disjoint ref device set, async
  spill, pending threshold epochs) == the same run with ``overlap=False``:
  same losses, same per-tensor rel-errs and thresholds in every online
  check, same threshold epochs, and — on a buggy run — the same first bad
  step out of bisection.
"""
import dataclasses

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.core.collector import Trace
from repro.core.merger import MergePlan, merge_microbatch_traces
from repro.parallel.pp1f1b import stage_tables

# ---------------------------------------------------------------------------
# MergePlan == merge_microbatch_traces (randomized structures)
# ---------------------------------------------------------------------------


def _rec(stage, mb, act=None, ag=None, pg=None):
    tr = Trace()
    if act:
        tr.activations = act
    if ag:
        tr.act_grads = ag
    if pg:
        tr.param_grads = pg
    return (stage, mb, tr)


def _random_records(rng, L, pp, M):
    """A plausible per-rank record set: per (stage, mb) one forward record
    (acts) and one backward record (act grads + param grads), values
    random."""
    tables = stage_tables(L, pp)
    recs = []
    for s in range(pp):
        n_local = len(tables[s])
        for m in range(M):
            acts = {f"layers.{i}.mlp/output":
                    rng.standard_normal((2, 3)).astype(np.float32)
                    for i in range(n_local)}
            if s == 0:
                acts["embedding/output"] = rng.standard_normal(
                    (2, 3)).astype(np.float32)
            pgs = {f"layers.{i}.mlp.down.w":
                   rng.standard_normal((3, 3)).astype(np.float32)
                   for i in range(n_local)}
            if s in (0, pp - 1):
                pgs["embedding.word_embeddings"] = rng.standard_normal(
                    (4, 3)).astype(np.float32)
            recs.append(_rec(s, m, act=acts))
            recs.append(_rec(s, m, ag=dict(acts), pg=pgs))
    return recs, tables


def _assert_merge_equal(recs, tables, M):
    m1, r1 = merge_microbatch_traces(recs, tables, M)
    plan = MergePlan.build(recs, tables, M)
    m2, r2 = plan.execute(recs)
    assert plan.executions == 1 and plan.fallbacks == 0
    for kind in ("activation", "act_grad", "param_grad"):
        s1, s2 = m1.section(kind), m2.section(kind)
        assert list(s1) == list(s2), kind
        for n in s1:
            np.testing.assert_array_equal(np.asarray(s1.raw(n)),
                                          np.asarray(s2.raw(n)),
                                          err_msg=f"{kind}/{n}")
    assert (r1.ok, r1.overlap, r1.omission) == (r2.ok, r2.overlap,
                                                r2.omission)
    assert r1.rank_problems == r2.rank_problems
    assert m1.meta["fwd_order"] == m2.meta["fwd_order"]
    assert m2.meta["merge_report"] is r2


@settings(max_examples=20, deadline=None)
@given(L=st.integers(2, 6), pp=st.integers(2, 3), M=st.integers(1, 3),
       mutation=st.sampled_from(["clean", "omission", "overlap",
                                 "out_of_grid", "collision"]),
       seed=st.integers(0, 10))
def test_merge_plan_matches_full_merge(L, pp, M, mutation, seed):
    rng = np.random.default_rng(seed)
    recs, tables = _random_records(rng, L, pp, M)
    if mutation == "omission":
        recs = recs[:-1]                           # drop one backward record
    elif mutation == "overlap":
        recs = recs + [recs[0]]                    # a record contributed twice
    elif mutation == "out_of_grid":
        recs = recs + [_rec(pp + 3, 0, act={
            "layers.0.mlp/output": np.ones((2, 3), np.float32)})]
    elif mutation == "collision":
        # a second stage claims a canonical name the first already produced
        x = np.asarray(recs[0][2].activations["layers.0.mlp/output"])
        bad = {"layers.0.mlp/output": x}
        # stage 1's local layers.0 canonicalizes to a later global index;
        # instead inject a non-layer name produced by BOTH stages
        bad = {"final_norm_out": x}
        recs = recs + [_rec(0, m, act=dict(bad)) for m in range(M)]
        recs = recs + [_rec(1, m, act=dict(bad)) for m in range(M)]
    _assert_merge_equal(recs, tables, M)


def test_merge_plan_executes_same_structure_repeatedly_and_falls_back():
    rng = np.random.default_rng(0)
    recs, tables = _random_records(rng, 4, 2, 2)
    plan = MergePlan.build(recs, tables, 2)
    for _ in range(3):
        merged, rep = plan.execute(recs)
        assert rep.ok
    assert plan.executions == 3
    # a structurally different record set falls back to the full merge
    merged, rep = plan.execute(recs[:-1])
    assert plan.fallbacks == 1
    assert not rep.ok and rep.omission          # full merge diagnosed it
    assert plan.stage_param_grads is None       # fallback invalidates reuse


# ---------------------------------------------------------------------------
# concurrent vs ordered 1F1B dispatch (engine level)
# ---------------------------------------------------------------------------


def _tiny_cfg(L):
    from repro.configs.base import get_config
    return dataclasses.replace(
        get_config("gpt-paper").reduced(), n_layers=L, d_model=64,
        n_heads=2, n_kv_heads=2, d_head=32, d_ff=128, vocab=128,
        tie_embeddings=True)


@pytest.mark.multidevice
@pytest.mark.parametrize("bugs", [frozenset(),
                                  frozenset(["pp_stale_boundary"]),
                                  frozenset(["pp_microbatch_order"])])
def test_concurrent_dispatch_bit_identical_to_ordered(forced_devices, bugs):
    """Dependency-driven dispatch preserves per-stage op order, so every
    trace leaf — clean or under the schedule bugs — is bit-identical to
    the clock-tick ordered drive."""
    import jax

    from repro.core.collector import flatten_named
    from repro.data.synthetic import make_batch
    from repro.models.model import Model
    from repro.parallel.pp1f1b import PP1F1BEngine
    cfg = _tiny_cfg(4)
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, 4, 16)
    tr_c, g_c, rep_c = PP1F1BEngine(m, params, batch, 2, 2, bugs).collect(
        params, batch)
    tr_o, g_o, rep_o = PP1F1BEngine(m, params, batch, 2, 2, bugs,
                                    dispatch="ordered").collect(params,
                                                                batch)
    assert rep_c.ok == rep_o.ok
    for kind in ("activation", "act_grad", "param_grad"):
        s_c, s_o = tr_c.section(kind), tr_o.section(kind)
        assert list(s_c) == list(s_o)
        for n in s_c:
            np.testing.assert_array_equal(np.asarray(s_c.raw(n)),
                                          np.asarray(s_o.raw(n)),
                                          err_msg=f"{kind}/{n}")
    gc, go = flatten_named(g_c), flatten_named(g_o)
    for n in gc:
        np.testing.assert_array_equal(np.asarray(gc[n]), np.asarray(go[n]),
                                      err_msg=n)
    assert float(tr_c.loss) == float(tr_o.loss)


# ---------------------------------------------------------------------------
# overlapped vs lockstep supervised runs
# ---------------------------------------------------------------------------


def _small_setup():
    import jax

    from repro.models.model import Model
    from repro.optim.adamw import AdamW
    cfg = dataclasses.replace(_tiny_cfg(2), vocab=256)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params, AdamW(lr=1e-3)


def _run_supervised(tmp_path, overlap, bug=None, steps=5,
                    reestimate_every=0):
    from repro.parallel.api import ParallelConfig
    from repro.supervise import Supervisor, SuperviseConfig
    cfg, model, params, opt = _small_setup()
    pcfg = ParallelConfig(bugs=frozenset([bug] if bug else []))
    sup = Supervisor(
        model, cfg, pcfg, opt, params=params,
        scfg=SuperviseConfig(steps=steps, overlap=overlap,
                             reestimate_every=reestimate_every,
                             stop_on_flag=bug is not None,
                             work_dir=str(tmp_path / f"ov{int(overlap)}")),
        batch_size=2, seq_len=16)
    return sup, sup.run()


def _assert_checks_identical(r1, r2):
    assert set(r1.checks) == set(r2.checks)
    for step in r1.checks:
        a, b = r1.checks[step], r2.checks[step]
        assert len(a.records) == len(b.records), step
        for ra, rb in zip(a.records, b.records):
            assert (ra.kind, ra.name) == (rb.kind, rb.name)
            assert ra.rel_err == rb.rel_err, (step, ra.name)
            assert ra.threshold == rb.threshold, (step, ra.name)
            assert ra.flagged == rb.flagged
        assert a.localized == b.localized


@pytest.mark.multidevice
def test_overlapped_clean_run_bit_identical_to_lockstep(forced_devices,
                                                        tmp_path):
    sup1, r1 = _run_supervised(tmp_path, overlap=True, reestimate_every=2,
                               steps=6)
    sup2, r2 = _run_supervised(tmp_path, overlap=False, reestimate_every=2,
                               steps=6)
    assert r1.passed and r2.passed
    assert r1.losses == r2.losses
    assert r1.cand_losses == r2.cand_losses
    _assert_checks_identical(r1, r2)
    # threshold epochs settle to the same schedule (pending vs immediate)
    assert r1.reestimations == r2.reestimations == 2
    e1, e2 = sup1.pipe._epochs, sup2.pipe._epochs
    assert [s for s, _, _ in e1] == [s for s, _, _ in e2]
    for (_, t1, m1), (_, t2, m2) in zip(e1, e2):
        assert t1.per_tensor == t2.per_tensor
        assert m1 == m2
    # the overlapped ring spilled through the background writer, and
    # flush() left the same disk state the synchronous writer leaves
    assert sup1.ring.on_disk == sup2.ring.on_disk


@pytest.mark.multidevice
def test_overlapped_buggy_run_same_first_bad_step(forced_devices, tmp_path):
    sup1, r1 = _run_supervised(tmp_path, overlap=True,
                               bug="ar_stale_recompute", steps=4)
    sup2, r2 = _run_supervised(tmp_path, overlap=False,
                               bug="ar_stale_recompute", steps=4)
    assert r1.flagged and r2.flagged
    assert r1.first_flagged_step == r2.first_flagged_step
    assert r1.first_bad_step == r2.first_bad_step == 0
    assert r1.localized_module == r2.localized_module
    _assert_checks_identical(r1, r2)


# ---------------------------------------------------------------------------
# background spill writer: pin races + flush
# ---------------------------------------------------------------------------


def _mk_trace(val):
    tr = Trace()
    tr.activations = {"m/x": np.full((4, 4), val, np.float32)}
    tr.loss, tr.grad_norm = float(val), 1.0
    return tr


def test_background_ring_pins_win_eviction_races(tmp_path):
    from repro.supervise.store import TraceRing
    ring = TraceRing(window=2, spill_dir=str(tmp_path), spill_keep=2,
                     background=True)
    for k in range(10):
        ring.put(k, _mk_trace(float(k)), _mk_trace(float(k)))
        if k == 4:
            # step 2 was just evicted: wherever it lives right now —
            # memory, writer queue, or disk — the pin must stick
            assert ring.pin(2)
    ring.flush()
    assert 2 in ring.on_disk                     # pinned survived pruning
    assert len([s for s in ring.on_disk if s != 2]) <= 2
    ref, _ = ring.get(2)
    assert ref.loss == 2.0
    # memory stayed flat: only the window lives in RAM after flush
    assert ring.in_memory == [8, 9]


def test_background_ring_get_serves_queued_steps(tmp_path):
    from repro.supervise.store import TraceRing
    ring = TraceRing(window=1, spill_dir=str(tmp_path), background=True)
    ring.put(0, _mk_trace(0.0), _mk_trace(0.0))
    ring.put(1, _mk_trace(1.0), _mk_trace(1.0))   # evicts 0 to the queue
    ref, _ = ring.get(0)                          # wherever it currently is
    assert ref.loss == 0.0
    ring.flush()
    ref, _ = ring.get(0)                          # now from disk
    assert ref.loss == 0.0


# ---------------------------------------------------------------------------
# pipeline: pending threshold epochs settle deterministically
# ---------------------------------------------------------------------------


def test_pending_epoch_settles_before_dependent_check():
    from repro.core import canonical as C
    from repro.core.thresholds import Thresholds
    from repro.supervise.pipeline import AsyncCheckPipeline
    pipe = AsyncCheckPipeline(Thresholds(eps=2.0 ** -24), window=2)
    fresh = Thresholds(eps=2.0 ** -24,
                       per_tensor={C.KIND_ACT: {"m/x": 0.125}})
    resolved = []

    def resolve():
        resolved.append(True)
        return fresh

    pipe.schedule_epoch(3, resolve)
    assert not resolved
    assert pipe.thresholds_for(2).per_tensor == {}      # before the epoch
    assert not resolved                                  # ... no settle
    thr = pipe.thresholds_for(3)                         # forces settlement
    assert resolved and thr.per_tensor[C.KIND_ACT]["m/x"] == 0.125
    assert pipe.epochs_settled == 1
    pipe.drain()
    assert pipe.epochs_settled == 1                      # nothing pending
