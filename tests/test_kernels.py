"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps + properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                       # no PyPI route in CI image
    from _hypothesis_compat import given, settings, strategies as st

from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.fp8_matmul import fp8_matmul
from repro.kernels.relerr import rel_err_fused
from repro.kernels.ssm_scan import gla_scan

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("B,S,H,Hkv,D", [
    (1, 128, 2, 2, 64), (2, 256, 4, 2, 64), (1, 256, 8, 2, 128),
    (1, 128, 4, 1, 64),
])
@pytest.mark.parametrize("mode,window", [("causal", 0), ("swa", 64),
                                         ("bidirectional", 0)])
def test_flash_attention_sweep(B, S, H, Hkv, D, mode, window):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, H, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, Hkv, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, Hkv, D), jnp.float32)
    o = flash_attention(q, k, v, mode=mode, window=window, bq=64, bk=64)
    r = ref.attention_ref(q, k, v, mode=mode, window=window)
    np.testing.assert_allclose(np.asarray(o), np.asarray(r), atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_dtypes(dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 128, 4, 64), dtype)
    k = jax.random.normal(ks[1], (1, 128, 2, 64), dtype)
    v = jax.random.normal(ks[2], (1, 128, 2, 64), dtype)
    o = flash_attention(q, k, v, bq=64, bk=64)
    r = ref.attention_ref(q, k, v)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(r, np.float32), atol=tol)


@pytest.mark.parametrize("dk,dv,chunk", [(16, 16, 32), (8, 32, 16),
                                         (32, 16, 64)])
@pytest.mark.parametrize("scalar,excl", [(True, False), (False, False),
                                         (False, True)])
def test_gla_scan_sweep(dk, dv, chunk, scalar, excl):
    B, S, H = 2, 128, 2
    ks = jax.random.split(KEY, 4)
    q = jax.random.normal(ks[0], (B, S, H, dk))
    k = jax.random.normal(ks[1], (B, S, H, dk))
    v = jax.random.normal(ks[2], (B, S, H, dv))
    if scalar:
        lw = -jax.nn.softplus(jax.random.normal(ks[3], (B, S, H, 1)))
    else:
        lw = -0.02 * jax.nn.sigmoid(jax.random.normal(ks[3], (B, S, H, dk)))
    y1, s1 = gla_scan(q, k, v, lw, chunk=chunk, exclusive=excl)
    y2, s2 = ref.gla_scan_ref(q, k, v, lw, exclusive=excl)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=5e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=5e-4)


@pytest.mark.parametrize("M,K,N,bm", [(128, 128, 128, 64), (64, 256, 192, 32),
                                      (256, 64, 64, 64)])
def test_fp8_matmul_sweep(M, K, N, bm):
    ks = jax.random.split(KEY, 2)
    x = (8 * jax.random.normal(ks[0], (M, K))).astype(jnp.float8_e4m3fn)
    w = (8 * jax.random.normal(ks[1], (K, N))).astype(jnp.float8_e4m3fn)
    o = fp8_matmul(x, w, bm=bm, bn=bm, bk=bm)
    r = ref.fp8_matmul_ref(x, w)
    np.testing.assert_allclose(np.asarray(o), np.asarray(r), atol=1e-2)


@given(n=st.integers(3, 4000), scale=st.floats(1e-6, 1e3))
@settings(max_examples=20, deadline=None)
def test_relerr_fused_property(n, scale):
    rng = np.random.default_rng(n)
    a = (rng.standard_normal(n) * scale).astype(np.float32)
    b = a + (rng.standard_normal(n) * scale * 1e-3).astype(np.float32)
    got = rel_err_fused(a, b, interpret=True)
    want = ref.rel_err_ref(a, b)
    assert got == pytest.approx(want, rel=1e-3, abs=1e-9)


def test_relerr_zero_reference():
    z = np.zeros(16, np.float32)
    b = np.ones(16, np.float32)
    assert rel_err_fused(z, b) == pytest.approx(4.0)   # ||a-b|| with ||a||=0


def test_ops_gla_rwkv_bonus_matches_model_impl():
    from repro.models.ssm import lin_attn_chunked
    B, S, H, dk, dv = 1, 64, 2, 8, 8
    ks = jax.random.split(KEY, 5)
    q = jax.random.normal(ks[0], (B, S, H, dk))
    k = jax.random.normal(ks[1], (B, S, H, dk))
    v = jax.random.normal(ks[2], (B, S, H, dv))
    lw = -0.01 * jax.nn.sigmoid(jax.random.normal(ks[3], (B, S, H, dk)))
    u = 0.3 * jnp.ones((H, dk))
    y1, s1 = ops.gla_scan(q, k, v, lw, chunk=16, exclusive=True, u=u)
    y2, s2 = lin_attn_chunked(q, k, v, lw, chunk=16, u=u)
    np.testing.assert_allclose(np.asarray(y1, np.float32),
                               np.asarray(y2, np.float32), atol=5e-4)
