"""Optimizer / data / checkpoint / precision substrates."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                       # no PyPI route in CI image
    from _hypothesis_compat import given, settings, strategies as st

from repro.configs.base import get_config
from repro.checkpoint.store import load_checkpoint, save_checkpoint
from repro.data.synthetic import DataLoader, make_batch
from repro.optim.adamw import AdamW, global_norm, warmup_cosine
from repro.parallel.zero import zero1_update
from repro.precision.fp8 import E4M3_MAX, fp8_linear, quantize_e4m3


# ---- optimizer -------------------------------------------------------------

def _toy():
    params = {"w": jnp.array([1.0, -2.0, 3.0]),
              "norm": jnp.array([1.0, 1.0])}
    grads = {"w": jnp.array([0.1, 0.2, -0.1]),
             "norm": jnp.array([0.01, -0.01])}
    return params, grads


def test_adamw_first_step_matches_closed_form():
    params, grads = _toy()
    opt = AdamW(lr=0.1, weight_decay=0.0, clip=0.0)
    st_ = opt.init(params)
    new, st2, info = opt.update(params, grads, st_)
    # step 1: m_hat = g, v_hat = g^2  ->  update ~= sign(g)
    expect = params["w"] - 0.1 * grads["w"] / (jnp.abs(grads["w"]) + 1e-8)
    np.testing.assert_allclose(np.asarray(new["w"]), np.asarray(expect),
                               rtol=1e-4)


def test_adamw_weight_decay_mask():
    params, grads = _toy()
    opt = AdamW(lr=0.1, weight_decay=0.5, clip=0.0)
    st_ = opt.init(params)
    zero_g = jax.tree.map(jnp.zeros_like, grads)
    new, _, _ = opt.update(params, zero_g, st_)
    assert float(jnp.abs(new["w"] - params["w"]).max()) > 0   # decayed
    np.testing.assert_allclose(np.asarray(new["norm"]),
                               np.asarray(params["norm"]))   # masked


def test_grad_clipping():
    params, grads = _toy()
    big = jax.tree.map(lambda g: g * 1e3, grads)
    opt = AdamW(lr=0.1, clip=1.0)
    _, _, info = opt.update(params, big, opt.init(params))
    assert float(info.grad_norm) == pytest.approx(1.0, rel=1e-4)
    assert float(info.pre_clip_norm) > 100


def test_main_grads_are_fp32():
    params = {"w": jnp.ones((4,), jnp.bfloat16)}
    grads = {"w": jnp.ones((4,), jnp.bfloat16)}
    opt = AdamW(lr=0.1)
    _, _, info = opt.update(params, grads, opt.init(params))
    assert info.main_grads["w"].dtype == jnp.float32


def test_warmup_cosine_schedule():
    lr = warmup_cosine(1.0, warmup=10, total=100)
    assert float(lr(0)) < float(lr(9)) <= 1.0
    assert float(lr(99)) < float(lr(50))


def test_zero1_equals_plain_adamw_without_bugs():
    params, grads = _toy()
    opt = AdamW(lr=0.1)
    p1, _, _ = opt.update(params, grads, opt.init(params))
    p2, _, _ = zero1_update(opt, params, grads, opt.init(params), dp=2)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b))


def test_zero1_skipped_update_bug_freezes_last_partition():
    params, grads = _toy()
    opt = AdamW(lr=0.1)
    p2, _, _ = zero1_update(opt, params, grads, opt.init(params), dp=3,
                            bugs=frozenset(["zero_skipped_update"]))
    w = np.asarray(p2["w"])
    assert w[2] == pytest.approx(3.0)          # last partition untouched
    assert w[0] != pytest.approx(1.0)


# ---- data ------------------------------------------------------------------

def test_data_determinism_and_shapes():
    cfg = get_config("tinyllama-1.1b").reduced()
    b1 = make_batch(cfg, 4, 32, seed=1, step=7)
    b2 = make_batch(cfg, 4, 32, seed=1, step=7)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    b3 = make_batch(cfg, 4, 32, seed=1, step=8)
    assert np.abs(np.asarray(b1["tokens"]) - np.asarray(b3["tokens"])).max() > 0
    assert b1["tokens"].shape == (4, 32)
    assert int(b1["tokens"].max()) < cfg.vocab


def test_data_modalities():
    acfg = get_config("hubert-xlarge").reduced()
    ab = make_batch(acfg, 2, 16)
    assert ab["features"].shape == (2, 16, acfg.audio_dim)
    assert ab["mask"].dtype == bool
    vcfg = get_config("llava-next-34b").reduced()
    vb = make_batch(vcfg, 2, 32)
    assert vb["image_embeds"].shape[-1] == vcfg.vision_dim
    assert vb["tokens"].shape[1] + vb["image_embeds"].shape[1] == 32


def test_dataloader_iterates():
    cfg = get_config("tinyllama-1.1b").reduced()
    from repro.configs.base import InputShape
    dl = DataLoader(cfg, InputShape("t", 16, 2, "train"))
    b0 = next(dl)
    b1 = next(dl)
    assert np.abs(np.asarray(b0["tokens"]) - np.asarray(b1["tokens"])).max() > 0


# ---- checkpoint --------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(10, dtype=jnp.float32).reshape(2, 5),
            "b": {"c": jnp.ones((3,), jnp.bfloat16)},
            "d": [jnp.zeros((2, 2)), jnp.full((1,), 7.0)]}
    save_checkpoint(str(tmp_path / "ck"), tree, step=42,
                    extra={"note": "hi"})
    back, step, extra = load_checkpoint(str(tmp_path / "ck"), tree)
    assert step == 42 and extra["note"] == "hi"
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_sharding_large_leaf(tmp_path):
    tree = {"big": jnp.arange(4096, dtype=jnp.float32).reshape(64, 64)}
    man = save_checkpoint(str(tmp_path / "ck"), tree, shard_bytes=4096)
    assert len(man["leaves"]["big"]["pieces"]) > 1
    back, _, _ = load_checkpoint(str(tmp_path / "ck"), tree)
    np.testing.assert_array_equal(np.asarray(back["big"]),
                                  np.asarray(tree["big"]))


# ---- fp8 ---------------------------------------------------------------------

@given(scale=st.floats(0.01, 100.0))
@settings(max_examples=20, deadline=None)
def test_quantize_dequantize_error_bounded(scale):
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.standard_normal((64, 64)).astype(np.float32) * scale)
    q, s = quantize_e4m3(x)
    back = q.astype(jnp.float32) * s
    rel = float(jnp.linalg.norm(back - x) / jnp.linalg.norm(x))
    assert rel < 0.08       # e4m3 relative precision


def test_quantize_respects_e4m3_range():
    x = jnp.asarray([[1e6, -1e6, 0.5]])
    q, s = quantize_e4m3(x)
    assert float(jnp.max(jnp.abs(q.astype(jnp.float32)))) <= E4M3_MAX


def test_fp8_linear_forward_close_backward_exact_dtype():
    rng = jax.random.PRNGKey(0)
    x = jax.random.normal(rng, (8, 16))
    p = {"w": 0.1 * jax.random.normal(jax.random.PRNGKey(1), (16, 4))}
    y = fp8_linear(p, x)
    exact = x @ p["w"]
    rel = float(jnp.linalg.norm(y - exact) / jnp.linalg.norm(exact))
    assert rel < 0.1
    g = jax.grad(lambda w: fp8_linear({"w": w}, x).sum())(p["w"])
    assert g.shape == p["w"].shape and bool(jnp.all(jnp.isfinite(g)))


def test_fp8_stale_scale_bug_degrades():
    rng = jax.random.PRNGKey(0)
    x = jax.random.normal(rng, (32, 32))
    w = 0.1 * jax.random.normal(jax.random.PRNGKey(1), (32, 32))
    from repro.precision.fp8 import fp8_matmul
    exact = x @ w
    good = fp8_matmul(x, w)
    bad = fp8_matmul(x, w, stale_scale=True)
    e_good = float(jnp.linalg.norm(good - exact))
    e_bad = float(jnp.linalg.norm(bad - exact))
    assert e_bad > 2 * e_good
