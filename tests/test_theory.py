"""Empirical checks of the paper's §5 theory.

* Thm 5.1 — transformer layers at standard init are smooth: Lipschitz-like
  amplification of a small random perturbation is 1 + O(d^-1/2).
* Thm 5.2 — accumulated FP (perturbation-induced) activation error grows at
  most ~linearly with depth, not exponentially.
* §5.2    — the perturbation estimator tracks actual FP round-off: a correct
  bf16 distributed-order difference stays within the estimated thresholds.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.core.harness import make_model_runner
from repro.core.thresholds import MACHINE_EPS, estimate_thresholds
from repro.data.synthetic import make_batch
from repro.models.model import Model, block_apply, block_init


def _amplification(d_model, key, n=8):
    cfg = dataclasses.replace(
        get_config("gpt-paper").reduced(), d_model=d_model,
        n_heads=max(2, d_model // 64), n_kv_heads=max(2, d_model // 64),
        d_head=min(64, d_model // 2), d_ff=2 * d_model, n_layers=1)
    p = block_init(key, cfg, "attn_mlp", jnp.float32)
    amps = []
    for i in range(n):
        kx, kd = jax.random.split(jax.random.fold_in(key, i))
        x = jax.random.normal(kx, (1, 32, d_model))
        dx = jax.random.normal(kd, x.shape) * 1e-4
        y0, _, _ = block_apply(p, cfg, "attn_mlp", x, None)
        y1, _, _ = block_apply(p, cfg, "attn_mlp", x + dx, None)
        amps.append(float(jnp.linalg.norm(y1 - y0) / jnp.linalg.norm(dx)))
    return float(np.mean(amps))


def test_thm51_layer_smoothness_at_init():
    """Amplification close to 1, and the excess shrinks as d grows."""
    key = jax.random.PRNGKey(0)
    a_small = _amplification(64, key)
    a_big = _amplification(256, key)
    assert a_small < 3.0 and a_big < 3.0       # C_l close to 1, not blowing up
    assert abs(a_big - 1.0) < abs(a_small - 1.0) + 0.5  # ~1 + O(d^-1/2)


def test_thm52_error_growth_subexponential():
    """Perturbation-induced relative activation error vs depth: the deep/
    shallow ratio must be far below exponential growth (2^L)."""
    eps = MACHINE_EPS["bfloat16"]
    cfg = dataclasses.replace(get_config("gpt-paper").reduced(),
                              n_layers=12, d_model=128, n_heads=4,
                              n_kv_heads=4, d_ff=256,
                              compute_dtype="bfloat16")
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    runner = make_model_runner(m, params)
    thr, _ = estimate_thresholds(runner, make_batch(cfg, 2, 32), eps)
    acts = thr.per_tensor["activation"]
    first = acts["layers.0.mlp/output"]
    last = acts["layers.11.mlp/output"]
    assert last / first < 12          # ~linear in L (12), << 2^12
    assert last < 100 * eps           # magnitude stays near machine eps


def test_estimator_covers_actual_bf16_reorder_noise():
    """Summing in a different order (the FP effect distribution introduces)
    stays under the estimated thresholds — no false positives (§5.2)."""
    eps = MACHINE_EPS["bfloat16"]
    cfg = dataclasses.replace(get_config("gpt-paper").reduced(),
                              n_layers=4, compute_dtype="bfloat16")
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, 2, 32)
    runner = make_model_runner(m, params)
    thr, base = estimate_thresholds(runner, batch, eps)
    # reorder-equivalent run: same math on permuted batch rows, un-permuted
    perm = np.array([1, 0])
    b2 = {k: np.asarray(v)[perm] for k, v in batch.items()}
    t2 = runner(b2, None)
    from repro.core.thresholds import rel_err
    for name, a in base.activations.items():
        b = t2.activations[name][np.argsort(perm)] \
            if t2.activations[name].shape[0] == 2 else t2.activations[name]
        if a.shape != b.shape:
            continue
        assert rel_err(a, b) <= thr.threshold("activation", name), name
