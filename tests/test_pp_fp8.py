"""Dedicated paths for the two remaining Table-1 rows: PP stage division
(bug 10) and the FP8 stale-scale cast (bug 8) — one-shot checks AND the
recipe-generic streaming supervisor driving both candidates."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_compat import given, settings, strategies as st

from repro.configs.base import get_config
from repro.core.collector import trace_fn_step
from repro.core.harness import make_model_runner, ttrace_check
from repro.core.tap import ensure_ctx
from repro.core.thresholds import MACHINE_EPS
from repro.data.synthetic import make_batch
from repro.models.model import Model
from repro.parallel.pp import (make_pp_runner, stage_division,
                               stage_layer_table)
from repro.precision.fp8 import fp8_linear


# ---------------------------------------------------------------------------
# bug 10: PP wrong stage division
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def gpt4():
    cfg = dataclasses.replace(get_config("gpt-paper").reduced(), n_layers=4,
                              vocab=256)
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, 2, 32)
    return cfg, m, params, batch


def test_stage_division_correct_and_buggy():
    assert stage_division(8, 2) == [(0, 4), (4, 8)]
    bad = stage_division(8, 2, bugs=frozenset(["pp_wrong_stage_division"]))
    (s0, e0), (s1, e1) = bad
    assert s1 < e0 or e1 < 8          # overlap or dropped tail


def test_stage_division_distributes_remainder():
    # L=10, pp=4 used to run only 8 layers (cpl = L // pp drops the tail);
    # the remainder now spreads one-per-stage from the front
    assert stage_division(10, 4) == [(0, 3), (3, 6), (6, 8), (8, 10)]
    # ... and stays distinguishable from the injected ceil-division bug
    bad = stage_division(10, 4, bugs=frozenset(["pp_wrong_stage_division"]))
    assert bad != stage_division(10, 4)
    ran = sorted(i for s, e in bad for i in range(s, e))
    assert ran != list(range(10))     # buggy division repeats/drops layers


@settings(max_examples=60, deadline=None)
@given(L=st.integers(1, 48), pp=st.integers(1, 12))
def test_stage_division_covers_every_layer_exactly_once(L, pp):
    pp = min(pp, L)
    stages = stage_division(L, pp)
    assert len(stages) == pp
    ran = [i for s, e in stages for i in range(s, e)]
    assert ran == list(range(L))      # exact, ordered, gap- and repeat-free
    # the canonical renaming table never collides (buggy overlaps spill to
    # fresh indices >= L instead of duplicating a tap name in one trace)
    for bugs in (frozenset(), frozenset(["pp_wrong_stage_division"])):
        table = stage_layer_table(L, pp, bugs)
        canons = [c for _, c in table]
        assert len(canons) == len(set(canons)), (L, pp, bugs, table)
    assert [e for e, _ in stage_layer_table(L, pp)] == list(range(L))
    if L % pp == 0:
        # the offset renaming coincides with the paper's canonical mapping
        from repro.core.canonical import canonical_layer_index
        cpl = L // pp
        for executed, canon in stage_layer_table(L, pp):
            r, local = divmod(executed, cpl)
            assert canon == canonical_layer_index(local, r, pp, 0, 1,
                                                  n_layers=L)


def test_pp_candidate_correct_division_passes(gpt4):
    cfg, m, params, batch = gpt4
    ref = make_model_runner(m, params)
    cand = make_pp_runner(m, params, pp_size=2)
    res = ttrace_check(ref, cand, batch, localize=False)
    assert res.passed, res.report.summary()


def test_pp_candidate_uneven_division_passes(gpt4):
    # 4 layers over 3 stages: sizes (2, 1, 1) — floor division would run
    # only 3 layers and flag a clean candidate
    cfg, m, params, batch = gpt4
    ref = make_model_runner(m, params)
    cand = make_pp_runner(m, params, pp_size=3)
    res = ttrace_check(ref, cand, batch, localize=False)
    assert res.passed, res.report.summary()


def test_pp_wrong_stage_division_detected_and_localized(gpt4):
    """Paper bug 10: one layer executes twice, another never runs — loss
    still finite/plausible, trace diverges exactly at the first misplaced
    layer's canonical name."""
    cfg, m, params, batch = gpt4
    ref = make_model_runner(m, params)
    cand = make_pp_runner(m, params, pp_size=2,
                          bugs=frozenset(["pp_wrong_stage_division"]))
    res = ttrace_check(ref, cand, batch, localize=False)
    assert not res.passed
    assert np.isfinite(res.candidate.loss)          # silent, not a crash
    # stage 1 re-executes layer 1 under canonical name layers.2
    assert res.report.localized.startswith("layers.2")


# ---------------------------------------------------------------------------
# bug 8: FP8 stale-scale cast (TTrace under an FP8 recipe, paper §6.7)
# ---------------------------------------------------------------------------

def _fp8_net(stale):
    def loss_call(params, batch, ctx):
        ctx = ensure_ctx(ctx)
        h = batch["x"]
        for i, p in enumerate(params["layers"]):
            with ctx.scope(f"layers.{i}.mlp"):
                h = ctx.tap("input", h)
                h = jax.nn.gelu(fp8_linear(p, h, stale_scale=stale))
                h = ctx.tap("output", h)
        return (h.astype(jnp.float32) ** 2).mean()
    return loss_call


def test_fp8_stale_scale_detected_with_bf16_thresholds():
    key = jax.random.PRNGKey(0)
    params = {"layers": [
        {"w": 0.2 * jax.random.normal(jax.random.fold_in(key, i), (64, 64))}
        for i in range(3)]}
    batch = {"x": jax.random.normal(jax.random.PRNGKey(9), (8, 64))}

    def runner(stale):
        def run(b, rewrites=None):
            tr, _, _ = trace_fn_step(_fp8_net(stale), params, b,
                                     rewrites=rewrites)
            return tr
        return run

    res = ttrace_check(runner(False), runner(False), batch,
                       eps=MACHINE_EPS["bfloat16"], localize=False)
    assert res.passed                      # correct fp8 recipe: no flags
    res2 = ttrace_check(runner(False), runner(True), batch,
                        eps=MACHINE_EPS["bfloat16"], localize=False)
    assert not res2.passed                 # stale amax cast flagged
    assert res2.report.localized.startswith("layers.0.mlp")


def test_fp8_matmul_tile128_kernel_matches_dequant():
    """The tile128 branch used to dispatch the Pallas kernel and then throw
    the result away; now the kernel applies the per-128-tile scales inside
    the K loop and must agree with the per-element dequant path."""
    from repro.precision.fp8 import fp8_matmul
    x = jax.random.normal(jax.random.PRNGKey(0), (256, 384))
    w = jax.random.normal(jax.random.PRNGKey(1), (384, 128))
    ref = fp8_matmul(x, w, recipe="tile128")
    ker = fp8_matmul(x, w, recipe="tile128", use_kernel=True)
    np.testing.assert_allclose(np.asarray(ker), np.asarray(ref),
                               rtol=1e-5, atol=1e-4)
    # non-128-divisible shapes fall back to the dequant path (same math)
    small = fp8_matmul(x[:100], w, recipe="tile128", use_kernel=True)
    np.testing.assert_allclose(np.asarray(small),
                               np.asarray(fp8_matmul(x[:100], w,
                                                     recipe="tile128")),
                               rtol=1e-6)


def test_tile128_ragged_dims_keep_true_tile_boundaries():
    """Compact-scale expansion must use the fixed 128 tile size, not
    ceil(M / num_tiles): with M=224 the tiles are rows [0,128) and
    [128,224), and a large value at row 120 must be dequantized with its
    OWN tile's scale — not clipped under the neighboring tile's."""
    from repro.precision.fp8 import expand_tile_scale, fp8_matmul, \
        quantize_e4m3
    x = np.full((224, 128), 0.01, np.float32)
    x[120, 0] = 100.0                       # large value inside tile 0
    q, s = quantize_e4m3(jnp.asarray(x), "tile128")
    assert s.shape == (2, 1)
    full = np.asarray(expand_tile_scale(s, x.shape))
    assert np.all(full[:128] == full[0, 0])         # true 128-row boundary
    assert np.all(full[128:] == full[-1, 0])
    out = np.asarray(fp8_matmul(jnp.asarray(x), jnp.eye(128),
                                recipe="tile128"))
    np.testing.assert_allclose(out[120, 0], 100.0, rtol=0.05)
    np.testing.assert_allclose(out[200, 0], 0.01, rtol=0.05)


# ---------------------------------------------------------------------------
# recipe-generic supervision: pp and fp8 candidates under the streaming
# supervisor (mid-run detection + first-bad-step bisection)
# ---------------------------------------------------------------------------

def _supervise(pcfg, params, model, cfg, steps=4, **scfg_kw):
    from repro.optim.adamw import AdamW
    from repro.supervise import Supervisor, SuperviseConfig
    sup = Supervisor(model, cfg, pcfg, AdamW(lr=1e-3), params=params,
                     scfg=SuperviseConfig(steps=steps, **scfg_kw),
                     batch_size=2, seq_len=16)
    return sup, sup.run()


@pytest.fixture(scope="module")
def gpt4_tied(gpt4):
    cfg, m, params, batch = gpt4
    cfg = dataclasses.replace(cfg, tie_embeddings=True)
    m = Model(cfg)
    return cfg, m, m.init(jax.random.PRNGKey(0))


def test_supervisor_pp_recipe_clean_and_buggy(gpt4_tied, tmp_path):
    from repro.parallel.api import ParallelConfig
    cfg, m, params = gpt4_tied
    _, res = _supervise(ParallelConfig(pp=2), params, m, cfg,
                        work_dir=str(tmp_path / "clean"))
    assert res.passed, res.summary()
    sup, res = _supervise(
        ParallelConfig(pp=2, bugs=frozenset(["pp_wrong_stage_division"])),
        params, m, cfg, work_dir=str(tmp_path / "bug"))
    assert res.flagged
    assert res.first_bad_step == 0          # wrong model from the start
    assert sup.candidate.name == "pp2"
    assert (res.localized_module or "").startswith("layers.")


def test_supervisor_fp8_recipe_clean_and_buggy(gpt4_tied, tmp_path):
    """FP8 recipes under the supervisor: BF16-epsilon thresholds selected
    automatically (paper §6.7), clean recipe passes, the stale-scale cast
    is caught mid-run and bisected."""
    from repro.parallel.api import ParallelConfig
    from repro.supervise import CandidateStep
    cfg, m, params = gpt4_tied
    sup, res = _supervise(ParallelConfig(fp8="tile128"), params, m, cfg,
                          work_dir=str(tmp_path / "clean"))
    assert res.passed, res.summary()
    assert sup.eps == MACHINE_EPS["bfloat16"]      # == fp8 recipe epsilon
    assert isinstance(sup.candidate, CandidateStep)
    sup, res = _supervise(
        ParallelConfig(fp8="tile128", bugs=frozenset(["fp8_stale_scale"])),
        params, m, cfg, work_dir=str(tmp_path / "bug"))
    assert res.flagged
    assert res.first_bad_step == 0
    assert (res.localized_module or "").startswith("layers.0.mlp")
