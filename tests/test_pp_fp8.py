"""Dedicated paths for the two remaining Table-1 rows: PP stage division
(bug 10) and the FP8 stale-scale cast (bug 8)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.core.collector import trace_fn_step
from repro.core.harness import make_model_runner, ttrace_check
from repro.core.tap import ensure_ctx
from repro.core.thresholds import MACHINE_EPS
from repro.data.synthetic import make_batch
from repro.models.model import Model
from repro.parallel.pp import make_pp_runner, stage_division
from repro.precision.fp8 import fp8_linear


# ---------------------------------------------------------------------------
# bug 10: PP wrong stage division
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def gpt4():
    cfg = dataclasses.replace(get_config("gpt-paper").reduced(), n_layers=4,
                              vocab=256)
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, 2, 32)
    return cfg, m, params, batch


def test_stage_division_correct_and_buggy():
    assert stage_division(8, 2) == [(0, 4), (4, 8)]
    bad = stage_division(8, 2, bugs=frozenset(["pp_wrong_stage_division"]))
    (s0, e0), (s1, e1) = bad
    assert s1 < e0 or e1 < 8          # overlap or dropped tail


def test_pp_candidate_correct_division_passes(gpt4):
    cfg, m, params, batch = gpt4
    ref = make_model_runner(m, params)
    cand = make_pp_runner(m, params, pp_size=2)
    res = ttrace_check(ref, cand, batch, localize=False)
    assert res.passed, res.report.summary()


def test_pp_wrong_stage_division_detected_and_localized(gpt4):
    """Paper bug 10: one layer executes twice, another never runs — loss
    still finite/plausible, trace diverges exactly at the first misplaced
    layer's canonical name."""
    cfg, m, params, batch = gpt4
    ref = make_model_runner(m, params)
    cand = make_pp_runner(m, params, pp_size=2,
                          bugs=frozenset(["pp_wrong_stage_division"]))
    res = ttrace_check(ref, cand, batch, localize=False)
    assert not res.passed
    assert np.isfinite(res.candidate.loss)          # silent, not a crash
    # stage 1 re-executes layer 1 under canonical name layers.2
    assert res.report.localized.startswith("layers.2")


# ---------------------------------------------------------------------------
# bug 8: FP8 stale-scale cast (TTrace under an FP8 recipe, paper §6.7)
# ---------------------------------------------------------------------------

def _fp8_net(stale):
    def loss_call(params, batch, ctx):
        ctx = ensure_ctx(ctx)
        h = batch["x"]
        for i, p in enumerate(params["layers"]):
            with ctx.scope(f"layers.{i}.mlp"):
                h = ctx.tap("input", h)
                h = jax.nn.gelu(fp8_linear(p, h, stale_scale=stale))
                h = ctx.tap("output", h)
        return (h.astype(jnp.float32) ** 2).mean()
    return loss_call


def test_fp8_stale_scale_detected_with_bf16_thresholds():
    key = jax.random.PRNGKey(0)
    params = {"layers": [
        {"w": 0.2 * jax.random.normal(jax.random.fold_in(key, i), (64, 64))}
        for i in range(3)]}
    batch = {"x": jax.random.normal(jax.random.PRNGKey(9), (8, 64))}

    def runner(stale):
        def run(b, rewrites=None):
            tr, _, _ = trace_fn_step(_fp8_net(stale), params, b,
                                     rewrites=rewrites)
            return tr
        return run

    res = ttrace_check(runner(False), runner(False), batch,
                       eps=MACHINE_EPS["bfloat16"], localize=False)
    assert res.passed                      # correct fp8 recipe: no flags
    res2 = ttrace_check(runner(False), runner(True), batch,
                        eps=MACHINE_EPS["bfloat16"], localize=False)
    assert not res2.passed                 # stale amax cast flagged
    assert res2.report.localized.startswith("layers.0.mlp")
