"""End-to-end behaviour: the training driver learns, serving decodes, and
TTrace is usable as a one-call regression check (paper §8 integration)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.core.harness import make_model_runner, ttrace_check
from repro.data.synthetic import make_batch
from repro.launch.steps import make_train_step
from repro.launch.train import main as train_main
from repro.models.model import Model
from repro.optim.adamw import AdamW


def test_training_reduces_loss():
    losses = train_main(["--arch", "gpt-paper", "--reduced", "--steps", "60",
                         "--batch", "8", "--seq", "64", "--lr", "1e-3",
                         "--log-every", "100"])
    assert np.mean(losses[-8:]) < np.mean(losses[:8]) - 0.05


def test_training_with_grad_accumulation_matches_full_batch():
    cfg = dataclasses.replace(get_config("gpt-paper").reduced(), n_layers=2,
                              vocab=256)
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    opt = AdamW(lr=1e-3)
    batch = make_batch(cfg, 8, 32)
    s1 = jax.jit(make_train_step(m, opt, n_micro=1))
    s4 = jax.jit(make_train_step(m, opt, n_micro=4))
    p1, _, m1 = s1(params, opt.init(params), batch)
    p4, _, m4 = s4(params, opt.init(params), batch)
    # equal-size microbatch shards -> the accumulated update must agree
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p4)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=2e-5)


def test_serve_driver_decodes():
    from repro.launch.serve import main as serve_main
    out = serve_main(["--arch", "tinyllama-1.1b", "--reduced", "--batch", "2",
                      "--prompt-len", "8", "--gen", "4"])
    assert out.shape == (2, 4)


def test_checkpoint_resume_training(tmp_path):
    ck = str(tmp_path / "ck")
    train_main(["--arch", "gpt-paper", "--reduced", "--steps", "5",
                "--batch", "4", "--seq", "32", "--save", ck,
                "--log-every", "100"])
    losses = train_main(["--arch", "gpt-paper", "--reduced", "--steps", "8",
                         "--batch", "4", "--seq", "32", "--resume", ck,
                         "--log-every", "100"])
    assert len(losses) == 3            # resumed at step 5 of 8


def test_ttrace_as_regression_check():
    """The <10-lines integration the paper advertises."""
    cfg = get_config("gpt-paper").reduced()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt = AdamW(lr=1e-3)
    state = opt.init(params)
    batch = make_batch(cfg, 2, 32)
    # --- the integration: 4 lines ---
    reference = make_model_runner(model, params, opt, state)
    candidate = make_model_runner(model, params, opt, state)
    result = ttrace_check(reference, candidate, batch, localize=False)
    assert result.passed
