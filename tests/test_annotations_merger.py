"""Shard specs, slicing, and the tensor merger (paper §4.1, Fig 6)."""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                       # no PyPI route in CI image
    from _hypothesis_compat import given, settings, strategies as st

from repro.core.annotations import (Annotations, ShardSpec, slices_for_rank)
from repro.core.generator import extract_shard, generate, perturb
from repro.core.merger import merge_shards


def _all_coords(sizes):
    import itertools
    axes = list(sizes)
    for combo in itertools.product(*(range(sizes[a]) for a in axes)):
        yield dict(zip(axes, combo)), tuple(combo)


@given(tp=st.sampled_from([1, 2, 4]), dim=st.sampled_from([0, 1, -1]))
@settings(max_examples=20, deadline=None)
def test_tp_slices_partition(tp, dim):
    spec = ShardSpec(tp_dim=dim)
    shape = (8, 12, 16)
    sizes = {"tp": tp}
    cover = np.zeros(shape, int)
    for coords, _ in _all_coords(sizes):
        for sl in slices_for_rank(spec, shape, sizes, coords):
            cover[sl] += 1
    assert (cover == 1).all()


def test_zigzag_cp_two_stripes():
    spec = ShardSpec(cp_dim=1, cp_mode="zigzag")
    shape = (2, 16, 4)
    sizes = {"cp": 2}
    frags0 = slices_for_rank(spec, shape, sizes, {"cp": 0})
    assert len(frags0) == 2
    # rank 0 owns chunks 0 and 3 of 4
    assert frags0[0][1] == slice(0, 4) and frags0[1][1] == slice(12, 16)
    frags1 = slices_for_rank(spec, shape, sizes, {"cp": 1})
    assert frags1[0][1] == slice(4, 8) and frags1[1][1] == slice(8, 12)


def test_merge_roundtrip_with_zigzag_and_tp():
    """generate -> shard per rank -> merge == original, no overlap/omission."""
    spec = ShardSpec(tp_dim=2, cp_dim=1, cp_mode="zigzag")
    shape = (2, 8, 8)
    sizes = {"cp": 2, "tp": 2}
    full = generate("t", shape)
    shards = {}
    for coords, ct in _all_coords(sizes):
        shards[ct] = extract_shard(full, spec, sizes, coords)
    merged, rep = merge_shards(shards, spec, sizes, shape)
    assert rep.ok, rep.problems()
    np.testing.assert_allclose(merged, full, rtol=1e-6)


def test_merger_detects_replica_conflict():
    """DP replicas must agree — a missing grad all-reduce shows up as a
    conflicting tensor (paper §4.4)."""
    spec = ShardSpec()   # fully replicated over dp
    shape = (4, 4)
    sizes = {"dp": 2}
    full = generate("u", shape)
    bad = full.copy()
    bad[0, 0] += 1.0
    _, rep = merge_shards({(0,): full, (1,): bad}, spec, sizes, shape)
    assert not rep.ok
    assert rep.conflicts and rep.conflicts[0]["coords"] == (1,)


def test_merger_detects_omission():
    spec = ShardSpec(tp_dim=0)
    shape = (4, 2)
    sizes = {"tp": 2}
    full = generate("v", shape)
    shards = {(0,): full[:2]}        # rank 1 missing
    _, rep = merge_shards(shards, spec, sizes, shape)
    assert not rep.ok and rep.omission == 4


def test_annotation_pattern_lookup():
    ann = Annotations.from_dict({
        "params": {"layers.*.mlp.down.w": {"tp_dim": 0}},
        "acts": {"layers.*.mlp/output": {"sp_dim": 1},
                 "layers.3.mlp/output": {"cp_dim": 1}},
    })
    assert ann.param_spec("layers.7.mlp.down.w").tp_dim == 0
    assert ann.param_spec("final_norm").tp_dim is None      # default
    # longest (most specific) pattern wins
    assert ann.act_spec("layers.3.mlp/output").cp_dim == 1
    assert ann.act_spec("layers.5.mlp/output").sp_dim == 1


def test_generator_determinism_and_perturb():
    a = generate("x", (16, 8))
    b = generate("x", (16, 8))
    np.testing.assert_array_equal(a, b)
    c = generate("y", (16, 8))
    assert np.abs(a - c).max() > 0
    p = perturb(a, 1e-3)
    rel = np.linalg.norm(p - a) / np.linalg.norm(a)
    assert 0.5e-3 < rel < 2e-3


def test_generate_shard_equals_extract():
    from repro.core.generator import generate_shard
    spec = ShardSpec(tp_dim=1)
    sizes = {"tp": 4}
    full = generate("w", (4, 16))
    for r in range(4):
        np.testing.assert_array_equal(
            generate_shard("w", (4, 16), spec, sizes, {"tp": r}),
            full[:, r * 4:(r + 1) * 4])
