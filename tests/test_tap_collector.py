"""Trace taps, zero-probe gradients, rewrite mode, collector."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.core.collector import (flatten_named, tap_shapes, trace_train_step,
                                  unflatten_named)
from repro.core.tap import TraceContext, ensure_ctx
from repro.data.synthetic import make_batch
from repro.models.model import Model
from repro.optim.adamw import AdamW


@pytest.fixture(scope="module")
def setup():
    cfg = dataclasses.replace(get_config("gpt-paper").reduced(), n_layers=2,
                              vocab=256)
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, 2, 16)
    return cfg, m, params, batch


def test_duplicate_tap_name_rejected():
    ctx = TraceContext("collect")
    x = jnp.ones((2,))
    with ctx.scope("a"):
        ctx.tap("out", x)
        with pytest.raises(ValueError, match="duplicate"):
            ctx.tap("out", x)


def test_tap_path_scoping():
    ctx = TraceContext("collect")
    with ctx.scope("layers.0"):
        with ctx.scope("mlp"):
            assert ctx.path("input") == "layers.0.mlp/input"
    assert ctx.path("top") == "top"


def test_probe_gradients_match_direct_grad(setup):
    """The zero-probe activation gradient must equal the directly computed
    jacobian-vector product gradient w.r.t. that activation."""
    cfg, m, params, batch = setup
    tr, _, _ = trace_train_step(m, params, batch)
    # direct: differentiate loss w.r.t. an injected delta at embedding output
    name = "embedding/output"

    def loss_with_delta(delta):
        ctx = TraceContext("collect", probes={name: delta})
        loss, _ = m.loss(params, batch, ctx=ctx)
        return loss

    zeros = jnp.zeros(tr.activations[name].shape, jnp.float32)
    g_direct = jax.grad(loss_with_delta)(zeros)
    np.testing.assert_allclose(np.asarray(g_direct), tr.act_grads[name],
                               rtol=1e-4, atol=1e-6)


def test_rewrite_mode_overwrites_value_straight_through(setup):
    cfg, m, params, batch = setup
    base, _, _ = trace_train_step(m, params, batch)
    name = "layers.1.mlp/input"
    new_val = np.zeros_like(base.activations[name])
    tr, _, _ = trace_train_step(m, params, batch,
                                rewrites={name: new_val})
    np.testing.assert_allclose(tr.activations[name], new_val, atol=1e-6)
    # upstream unaffected; downstream recomputed from the rewrite
    np.testing.assert_allclose(tr.activations["embedding/output"],
                               base.activations["embedding/output"])
    assert np.abs(tr.activations["final_norm_out"]
                  - base.activations["final_norm_out"]).max() > 1e-6
    # gradient flow preserved (straight-through): act grads still exist and
    # embedding still receives gradient
    assert np.isfinite(tr.param_grads["embedding.word_embeddings"]).all()


def test_trace_sections_complete(setup):
    cfg, m, params, batch = setup
    opt = AdamW(lr=1e-3)
    tr, new_p, new_s = trace_train_step(m, params, batch, opt=opt,
                                        opt_state=opt.init(params))
    assert tr.activations and tr.act_grads and tr.param_grads
    assert tr.main_grads and tr.params_post
    assert np.isfinite(tr.loss)
    assert set(tr.param_grads) == set(tr.main_grads) == set(tr.params_post)
    # forward order recorded and starts at the embedding
    assert tr.meta["fwd_order"][0] == "embedding/output"


def test_flatten_unflatten_roundtrip(setup):
    cfg, m, params, _ = setup
    named = flatten_named(params)
    back = unflatten_named(named, params)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
