"""Cross-recipe supervised bug-coverage matrix (ISSUE 4).

Every ``bugs/registry.py`` entry must be

  (a) expressible by at least one candidate recipe,
  (b) flagged by the streaming supervisor under that recipe, and
  (c) localized to its ``expected_module``;

and bug/recipe combinations that CANNOT express a bug must hit the CLI
refusal path (never a meaningless clean pass).  The candidate table below is
derived from each bug's ``requires`` at collection time, so registering a
future bug without a supervised e2e path fails ``test_every_bug_has_a_
supervised_recipe`` immediately.
"""
import dataclasses
import fnmatch

import pytest

from repro.bugs.registry import BUGS

# ---------------------------------------------------------------------------
# candidate table: ordered; the FIRST entry whose features cover a bug's
# ``requires`` runs it.  (name, pcfg kwargs, needs_moe_arch)
# ---------------------------------------------------------------------------

CANDIDATES = [
    ("dense dp2tp2", dict(dp=2, tp=2), False),
    ("dense dp2tp2sp", dict(dp=2, tp=2, sp=True), False),
    ("dense dp2cp2tp2", dict(dp=2, cp=2, tp=2), False),
    ("zero1 dp2", dict(dp=2, zero1=True), False),
    ("moe tp2", dict(tp=2), True),
    ("pp staged", dict(pp=2), False),
    ("pp-1f1b", dict(pp=2, pp_schedule="1f1b", microbatches=2), False),
    ("fp8 tile128", dict(fp8="tile128"), False),
]


def _features(kwargs, moe):
    from repro.parallel.api import ParallelConfig
    return (ParallelConfig(**kwargs).features
            | ({"moe"} if moe else set()))


def candidate_for(spec):
    for name, kwargs, moe in CANDIDATES:
        if set(spec.requires) <= _features(kwargs, moe):
            return name, kwargs, moe
    return None


# a bug whose only effect is a wrong parameter UPDATE has no forward /
# backward trace to blame: propagation localization correctly names the
# optimizer stage (the paper's step report does the same for ZeRO bugs)
def _loc_ok(spec, loc):
    if spec.expected_module == "loss":
        return True                     # loss-scaling family: no module
    if fnmatch.fnmatchcase(loc, spec.expected_module):
        return True
    return loc == "optimizer" and "update" in spec.impact


def test_every_bug_has_a_supervised_recipe():
    missing = [bid for bid, spec in BUGS.items()
               if candidate_for(spec) is None]
    assert not missing, (
        f"bugs {missing} are not expressible by any supervised candidate "
        f"recipe — extend CANDIDATES in this matrix (and the recipe "
        f"implementations) when registering new bugs")


# ---------------------------------------------------------------------------
# supervised e2e per bug
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def setups():
    """Lazily-built (cfg, model, params) per arch, shared across bugs."""
    import jax

    from repro.configs.base import MoEConfig, get_config
    from repro.models.model import Model
    cache = {}

    def get(moe: bool, n_layers: int):
        key = (moe, n_layers)
        if key not in cache:
            cfg = dataclasses.replace(get_config("gpt-paper").reduced(),
                                      n_layers=n_layers, vocab=256,
                                      tie_embeddings=True)
            if moe:
                cfg = dataclasses.replace(
                    cfg, arch_type="moe",
                    moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=128,
                                  capacity_factor=0.0))
            m = Model(cfg)
            cache[key] = (cfg, m, m.init(jax.random.PRNGKey(0)))
        return cache[key]

    return get


@pytest.mark.multidevice
@pytest.mark.parametrize("bug_id", sorted(BUGS))
def test_bug_flagged_and_localized_under_supervision(
        forced_devices, setups, tmp_path, bug_id):
    from repro.optim.adamw import AdamW
    from repro.parallel.api import ParallelConfig
    from repro.supervise import Supervisor, SuperviseConfig
    spec = BUGS[bug_id]
    picked = candidate_for(spec)
    assert picked is not None, f"no recipe expresses {bug_id}"
    name, kwargs, moe = picked
    # pipeline recipes need >= 2 layers per meaningful stage
    n_layers = 4 if "pp" in spec.requires else 2
    cfg, model, params = setups(moe, n_layers)
    pcfg = ParallelConfig(bugs=frozenset([bug_id]), **kwargs)
    sup = Supervisor(model, cfg, pcfg, AdamW(lr=1e-3), params=params,
                     scfg=SuperviseConfig(steps=3, ckpt_every=2,
                                          work_dir=str(tmp_path)),
                     batch_size=2 if pcfg.pp == 1 else 4, seq_len=16)
    res = sup.run()
    assert res.flagged, (f"{bug_id} NOT flagged under {name}:\n"
                         + res.summary())
    assert res.first_bad_step is not None
    loc = res.localized_module or "-"
    assert _loc_ok(spec, loc), (
        f"{bug_id} under {name}: localized to {loc!r}, expected "
        f"{spec.expected_module!r}\n" + res.summary())


# ---------------------------------------------------------------------------
# unexpressible combinations must hit the CLI refusal path (PR 3 contract)
# ---------------------------------------------------------------------------

def _cli_args(**over):
    import argparse
    ns = argparse.Namespace(
        arch=None, recipe=None, bug=None, dp=None, cp=None, tp=None,
        sp=False, zero1=False, pp=2, microbatches=4, batch=4)
    for k, v in over.items():
        setattr(ns, k, v)
    return ns


@pytest.mark.parametrize("over", [
    # shard_map bug under a non-shard_map recipe
    dict(recipe="fp8-global", bug="tp_missing_row_psum"),
    dict(recipe="pp", bug="tp_wrong_embedding_mask"),
    dict(recipe="pp-1f1b", bug="zero_skipped_update"),
    # 1F1B schedule bugs need the 1F1B engine, not the staged candidate
    dict(recipe="pp", bug="pp_microbatch_order"),
    dict(recipe="pp", bug="pp_stale_boundary"),
    # recipe bug under an explicit conflicting recipe
    dict(recipe="dense", bug="pp_stale_boundary"),
    dict(recipe="fp8-tile128", bug="pp_wrong_stage_division"),
    # shard_map flags refused for pipeline/fp8 recipes
    dict(recipe="pp-1f1b", tp=2),
    # 1F1B needs >= 2 microbatches dividing the batch
    dict(recipe="pp-1f1b", microbatches=1),
    dict(recipe="pp-1f1b", microbatches=3, batch=4),
    # a bug whose features the built candidate cannot express
    dict(recipe="dense", bug="fp8_stale_scale"),
])
def test_unexpressible_combinations_hit_the_cli_refusal_path(over):
    from repro.launch.supervise import build_pcfg
    args = _cli_args(**over)
    requires = set(BUGS[args.bug].requires) if args.bug else set()
    with pytest.raises(SystemExit):
        build_pcfg(args, requires)


def test_bug_pulls_its_recipe_in_without_explicit_flag():
    """--bug pp_stale_boundary alone must drive the 1F1B engine."""
    from repro.launch.supervise import build_pcfg
    args = _cli_args(bug="pp_stale_boundary")
    recipe, pcfg = build_pcfg(args,
                              set(BUGS["pp_stale_boundary"].requires))
    assert recipe == "pp-1f1b"
    assert pcfg.recipe_kind == "pp_1f1b"
    assert pcfg.microbatches == 4
