"""Packed segmented rel-err kernel + batched checking engine + lazy Trace."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                       # no PyPI route in CI image
    from _hypothesis_compat import given, settings, strategies as st

from repro.core import canonical as C
from repro.core.checker import CheckRecord, compare_traces
from repro.core.collector import Section, Trace, trace_pair_step, \
    trace_train_step
from repro.core.relerr_engine import (batched_rel_err, pack_device,
                                      rel_err_np, section_sq_norms)
from repro.core.thresholds import Thresholds
from repro.kernels.relerr import DEFAULT_BLOCK, packed_sq_norms, \
    packed_sq_norms_xla, sq_norms

BLOCK = DEFAULT_BLOCK


def _pairs(sizes, seed=0, dtype=np.float32, rel=1e-3):
    rng = np.random.default_rng(seed)
    out = []
    for n in sizes:
        a = (rng.standard_normal(n) * rng.uniform(0.01, 10)).astype(dtype)
        b = (a.astype(np.float32)
             + rel * rng.standard_normal(n).astype(np.float32)).astype(dtype)
        out.append((a, b))
    return out


def _ref_sq(pairs):
    out = np.empty((len(pairs), 2), np.float64)
    for i, (a, b) in enumerate(pairs):
        a64, b64 = np.asarray(a, np.float64), np.asarray(b, np.float64)
        out[i] = [np.sum((a64 - b64) ** 2), np.sum(a64 ** 2)]
    return out


# ---------------------------------------------------------------------------
# packed segmented kernel
# ---------------------------------------------------------------------------

@given(seed=st.integers(0, 1000),
       dtype=st.sampled_from([np.float32, "bfloat16"]))
@settings(max_examples=8, deadline=None)
def test_packed_kernel_ragged_sizes_property(seed, dtype):
    if dtype == "bfloat16":
        dtype = jnp.bfloat16
    sizes = [1, BLOCK - 1, BLOCK, BLOCK + 1, 3 * BLOCK + 17, 5]
    pairs = [(jnp.asarray(a, dtype), jnp.asarray(b, dtype))
             for a, b in _pairs(sizes, seed=seed)]
    af, bf, seg, cnt = pack_device([a for a, _ in pairs],
                                   [b for _, b in pairs])
    got = np.asarray(packed_sq_norms(af, bf, seg, cnt,
                                     n_segments=len(pairs)), np.float64)
    want = _ref_sq(pairs)
    tol = 1e-4 if dtype == np.float32 else 2e-2
    np.testing.assert_allclose(got, want, rtol=tol, atol=1e-12)


def test_packed_kernel_matches_xla_oracle():
    sizes = [7, BLOCK, 2 * BLOCK + 3]
    pairs = _pairs(sizes, seed=3)
    af, bf, seg, cnt = pack_device([jnp.asarray(a) for a, _ in pairs],
                                   [jnp.asarray(b) for _, b in pairs])
    kern = np.asarray(packed_sq_norms(af, bf, seg, cnt, n_segments=3))
    orac = np.asarray(packed_sq_norms_xla(af, bf, seg, n_segments=3))
    np.testing.assert_allclose(kern, orac, rtol=1e-6)


def test_packed_kernel_masks_padding_garbage():
    """NaN in the padding tail must not leak into any pair's sums."""
    n = BLOCK + 5
    a = np.ones(n, np.float32)
    b = np.full(n, 2.0, np.float32)
    af = np.full(2 * BLOCK, np.nan, np.float32)
    bf = np.full(2 * BLOCK, np.nan, np.float32)
    af[:n], bf[:n] = a, b
    seg = jnp.asarray([0, 0], jnp.int32)
    cnt = jnp.asarray([BLOCK, n - BLOCK], jnp.int32)
    out = np.asarray(packed_sq_norms(jnp.asarray(af), jnp.asarray(bf),
                                     seg, cnt, n_segments=1))
    np.testing.assert_allclose(out[0], [n, n], rtol=1e-6)


def test_packed_kernel_zero_reference_and_empty():
    z = jnp.zeros(16, jnp.float32)
    o = jnp.ones(16, jnp.float32)
    e = jnp.zeros(0, jnp.float32)
    af, bf, seg, cnt = pack_device([z, e], [o, e])
    out = np.asarray(packed_sq_norms(af, bf, seg, cnt, n_segments=2))
    np.testing.assert_allclose(out[0], [16.0, 0.0], rtol=1e-6)
    np.testing.assert_allclose(out[1], [0.0, 0.0])


def test_single_pair_sq_norms_wrapper():
    a, b = _pairs([4 * BLOCK + 11], seed=7)[0]
    d2, a2 = sq_norms(a, b)
    want = _ref_sq([(a, b)])[0]
    np.testing.assert_allclose([float(d2), float(a2)], want, rtol=1e-4)


# ---------------------------------------------------------------------------
# engine: mode agreement + section semantics
# ---------------------------------------------------------------------------

@given(seed=st.integers(0, 10_000), rel=st.floats(1e-7, 1e-1))
@settings(max_examples=8, deadline=None)
def test_engine_modes_agree_property(seed, rel):
    sizes = [1, 3, BLOCK - 1, BLOCK + 1, 2000]
    pairs = _pairs(sizes, seed=seed, rel=rel)
    sec_a = {f"t{i}": a for i, (a, _) in enumerate(pairs)}
    sec_b = {f"t{i}": b for i, (_, b) in enumerate(pairs)}
    want = {k: rel_err_np(sec_a[k], sec_b[k]) for k in sec_a}
    for mode in ("loop", "blas", "fused", "packed"):
        got = batched_rel_err(sec_a, sec_b, mode=mode)
        for k in want:
            assert got[k] == pytest.approx(want[k], rel=1e-3, abs=1e-10), \
                (mode, k)


def test_engine_auto_mode_runs():
    pairs = _pairs([64, 128], seed=1)
    sec_a = {f"t{i}": a for i, (a, _) in enumerate(pairs)}
    sec_b = {f"t{i}": b for i, (_, b) in enumerate(pairs)}
    got = batched_rel_err(sec_a, sec_b)            # backend/size auto-select
    want = {k: rel_err_np(sec_a[k], sec_b[k]) for k in sec_a}
    for k in want:
        assert got[k] == pytest.approx(want[k], rel=1e-6)


def test_engine_empty_section():
    assert batched_rel_err({}, {}) == {}
    assert section_sq_norms([], []).shape == (0, 2)


# ---------------------------------------------------------------------------
# compare_traces regression: identical Report records vs the old loop
# ---------------------------------------------------------------------------

def _compare_traces_legacy(ref, cand, thr, kinds):
    """The pre-refactor per-tensor float64 loop, verbatim semantics."""
    records, missing = [], []
    for kind in kinds:
        rs, cs = ref.section(kind), cand.section(kind)
        for name, a in rs.items():
            if name not in cs:
                missing.append(f"{kind}:{name} missing from candidate")
                continue
            b = cs[name]
            if a.shape != b.shape:
                records.append(CheckRecord(
                    kind, name, float("inf"), 0.0, True,
                    note=f"shape {b.shape} != ref {a.shape}"))
                continue
            e = rel_err_np(a, b)
            t = thr.threshold(kind, name)
            records.append(CheckRecord(kind, name, e, t, e > t))
    return records, missing


def _build_regression_traces():
    rng = np.random.default_rng(5)
    ref, cand = Trace(), Trace()
    acts_r, acts_c = {}, {}
    for i in range(40):
        n = int(rng.integers(1, 3000))
        a = rng.standard_normal(n).astype(np.float32)
        scale = 1e-7 if i % 3 else 1e-2          # mixed pass/fail
        acts_r[f"layers.{i}.mlp/output"] = a
        acts_c[f"layers.{i}.mlp/output"] = \
            a + scale * rng.standard_normal(n).astype(np.float32)
    acts_c["layers.0.mlp/output"] = np.zeros((2, 2), np.float32)  # shape mism
    acts_r["only_ref/output"] = np.ones(4, np.float32)            # missing
    ref.activations, cand.activations = acts_r, acts_c
    ref.meta["fwd_order"] = list(acts_r)
    return ref, cand, Thresholds(eps=2.0 ** -24)


def _assert_matches_legacy(ref, cand, thr, rel_err_tol):
    rep = compare_traces(ref, cand, thr, kinds=(C.KIND_ACT,))
    legacy_records, legacy_missing = _compare_traces_legacy(
        ref, cand, thr, kinds=(C.KIND_ACT,))

    assert rep.missing == legacy_missing
    assert len(rep.records) == len(legacy_records)
    for got, want in zip(rep.records, legacy_records):
        assert (got.kind, got.name, got.note) == \
            (want.kind, want.name, want.note)
        assert got.threshold == want.threshold
        assert got.flagged == want.flagged       # bit-identical flag decision
        if np.isfinite(want.rel_err):
            assert got.rel_err == pytest.approx(want.rel_err,
                                                rel=rel_err_tol, abs=1e-12)


def test_compare_traces_matches_legacy_loop():
    ref, cand, thr = _build_regression_traces()
    # sections are below the engine cutoff -> auto mode is the float64 loop
    _assert_matches_legacy(ref, cand, thr, rel_err_tol=1e-6)


def test_compare_traces_matches_legacy_on_batched_path(monkeypatch):
    """Flag parity must hold on the batched executor production traces
    actually take (above-cutoff sections), not just the float64 loop."""
    from repro.core import relerr_engine
    monkeypatch.setattr(relerr_engine, "MIN_BATCHED_ELEMS",
                        {k: 0 for k in relerr_engine.MIN_BATCHED_ELEMS})
    ref, cand, thr = _build_regression_traces()
    _assert_matches_legacy(ref, cand, thr, rel_err_tol=1e-4)


# ---------------------------------------------------------------------------
# lazy Trace contract
# ---------------------------------------------------------------------------

def test_section_lazy_host_boundary():
    s = Section({"x": jnp.arange(6.0), "y": np.ones(3)})
    assert isinstance(s.raw("x"), jax.Array)     # no transfer on raw access
    assert s.shape_of("x") == (6,)
    assert not s._host                            # nothing materialized yet
    h = s["x"]
    assert isinstance(h, np.ndarray)
    assert s["x"] is h                            # cached
    s["x"] = jnp.zeros(2)                         # write invalidates cache
    np.testing.assert_allclose(s["x"], np.zeros(2))
    assert set(s.host()) == {"x", "y"}


def test_trace_adopts_plain_dicts():
    t = Trace()
    t.activations = {"a/output": np.ones(2, np.float32)}
    assert isinstance(t.activations, Section)
    t2 = Trace(activations={"b/output": jnp.ones(2)})
    assert isinstance(t2.activations, Section)
    assert isinstance(t2.host().activations["b/output"], np.ndarray)


def test_compare_traces_does_not_materialize_device_sections():
    """A full check of matching device-resident sections must not populate
    any host cache — only the N x 2 reduction scalars come back."""
    leaves = {f"t{i}/output": jnp.asarray(
        np.random.default_rng(i).standard_normal(500).astype(np.float32))
        for i in range(8)}
    ref, cand = Trace(), Trace()
    ref.activations = dict(leaves)
    cand.activations = dict(leaves)
    ref.meta["fwd_order"] = list(leaves)
    rep = compare_traces(ref, cand, Thresholds(eps=2.0 ** -24),
                         kinds=(C.KIND_ACT,))
    assert rep.passed
    assert not ref.activations._host and not cand.activations._host


def test_collector_sections_stay_device_resident():
    cfg = dataclasses.replace(
        __import__("repro.configs.base", fromlist=["get_config"])
        .get_config("gpt-paper").reduced(), n_layers=1, vocab=128)
    from repro.models.model import Model
    from repro.data.synthetic import make_batch
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    tr, _, _ = trace_train_step(m, params, make_batch(cfg, 2, 8))
    for name in tr.activations:
        assert isinstance(tr.activations.raw(name), jax.Array)
    assert not tr.activations._host


# ---------------------------------------------------------------------------
# fused pair collection == two serial steps
# ---------------------------------------------------------------------------

def test_trace_pair_step_matches_serial():
    cfg = dataclasses.replace(
        __import__("repro.configs.base", fromlist=["get_config"])
        .get_config("gpt-paper").reduced(), n_layers=1, vocab=128)
    from repro.models.model import Model
    from repro.data.synthetic import make_batch
    from repro.optim.adamw import AdamW
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    opt = AdamW(lr=1e-3)
    st_ = opt.init(params)
    b1 = make_batch(cfg, 2, 8, seed=0)
    b2 = make_batch(cfg, 2, 8, seed=1)
    batch2 = {k: np.stack([np.asarray(b1[k]), np.asarray(b2[k])])
              for k in b1}
    p1, p2 = trace_pair_step(m, params, batch2, opt=opt, opt_state=st_)
    s1, _, _ = trace_train_step(m, params, b1, opt=opt, opt_state=st_)
    s2, _, _ = trace_train_step(m, params, b2, opt=opt, opt_state=st_)
    for pair_tr, ser_tr in ((p1, s1), (p2, s2)):
        assert pair_tr.loss == pytest.approx(ser_tr.loss, rel=1e-5)
        assert pair_tr.grad_norm == pytest.approx(ser_tr.grad_norm, rel=1e-4)
        for kind in (C.KIND_ACT, C.KIND_ACT_GRAD, C.KIND_PARAM_GRAD,
                     C.KIND_MAIN_GRAD, C.KIND_PARAM_POST):
            ps, ss = pair_tr.section(kind), ser_tr.section(kind)
            assert set(ps) == set(ss)
            # post-step params pass through Adam's m/sqrt(v) normalization:
            # on the FIRST step u = g/(|g|+eps) ~= sign(g), so an element
            # whose vmapped-vs-serial gradient reassociation noise straddles
            # zero moves the update by up to 2*lr in ABSOLUTE terms — no
            # rtol absorbs that, and which elements flip varies with the
            # compile's reduction tiling (8-forced-device CPU).  Bound the
            # kind by its mathematical worst case, 2*lr (+ margin); the
            # production checker widens this kind the same way
            # (thresholds.Thresholds.kind_margins).
            atol = 2.5e-3 if kind == C.KIND_PARAM_POST else 2e-5
            for name in ps:
                np.testing.assert_allclose(
                    np.asarray(ps[name], np.float32),
                    np.asarray(ss[name], np.float32),
                    rtol=2e-4, atol=atol, err_msg=f"{kind}:{name}")
