"""HLO collective parsing + GSPMD sharding rules."""
import jax
import numpy as np
import pytest

from repro.launch.hlo import parse_hlo_collectives, shape_bytes
from repro.sharding import rules
from jax.sharding import PartitionSpec as P

HLO = """
HloModule test

ENTRY %main (p0: f32[64,128]) -> f32[64,128] {
  %p0 = f32[64,128]{1,0} parameter(0)
  %ag = f32[64,2048]{1,0} all-gather(f32[64,128]{1,0} %p0), replica_groups={}
  %ar = f32[64,128]{1,0} all-reduce(f32[64,128]{1,0} %p0), to_apply=%sum
  %rs = f32[4,128]{1,0} reduce-scatter(f32[64,128]{1,0} %p0), dimensions={0}
  %cp = f32[64,128]{1,0} collective-permute(f32[64,128]{1,0} %p0)
  %a2a = f32[64,128]{1,0} all-to-all(f32[64,128]{1,0} %p0), dimensions={0}
  ROOT %out = f32[64,128]{1,0} add(%ar, %cp)
}
"""


def test_shape_bytes():
    assert shape_bytes("f32[64,128]{1,0}") == 64 * 128 * 4
    assert shape_bytes("bf16[8]") == 16
    assert shape_bytes("(f32[2,2], bf16[4])") == 16 + 8
    assert shape_bytes("pred[]") == 1


def test_parse_collectives_counts_and_bytes():
    got = parse_hlo_collectives(HLO)
    n = 64 * 128 * 4
    assert got["all-gather"]["count"] == 1
    assert got["all-gather"]["operand_bytes"] == n
    assert got["all-gather"]["result_bytes"] == 64 * 2048 * 4
    assert got["all-reduce"]["count"] == 1
    assert got["reduce-scatter"]["count"] == 1
    assert got["collective-permute"]["count"] == 1
    assert got["all-to-all"]["count"] == 1
    assert got["total"]["count"] == 5
    assert got["total"]["operand_bytes"] == 5 * n


def test_parse_real_jit_hlo():
    """An actually-compiled psum should be found by the parser."""
    import jax.numpy as jnp
    mesh = jax.make_mesh((1,), ("x",))
    # single-device: use a sharded matmul that forces no collectives,
    # then just assert the parser runs on real HLO without error
    c = jax.jit(lambda a: a @ a).lower(
        jax.ShapeDtypeStruct((64, 64), jnp.float32)).compile()
    got = parse_hlo_collectives(c.as_text())
    assert got["total"]["count"] == 0


# ---- sharding rules ---------------------------------------------------------

class _FakeMesh:
    axis_names = ("data", "model")
    shape = {"data": 16, "model": 16}


def test_param_rules_basic():
    mesh = _FakeMesh()
    assert rules.param_pspec("embedding.word_embeddings", (32000, 4096),
                             mesh) == P("model", None)
    assert rules.param_pspec("layers.0.self_attention.linear_qkv.w",
                             (4096, 6144), mesh) == P(None, "model")
    # scan-stacked leaf: leading layer dim replicated
    assert rules.param_pspec("layers.self_attention.linear_qkv.w",
                             (32, 4096, 6144), mesh) == P(None, None, "model")
    # norm weights replicated
    assert rules.param_pspec("layers.0.input_norm", (4096,), mesh) == P(None)


def test_param_rules_fallback_alternatives():
    mesh = _FakeMesh()
    # 8 experts don't divide 16 -> fall back to sharding the ffn dim
    assert rules.param_pspec("layers.mlp.experts.gate", (32, 8, 4096, 14336),
                             mesh) == P(None, None, None, "model")
    # 160 experts divide 16 -> expert-parallel
    assert rules.param_pspec("layers.mlp.experts.gate", (59, 160, 5120, 1536),
                             mesh) == P(None, "model", None, None)


def test_param_rules_nondivisible_replicates():
    mesh = _FakeMesh()
    assert rules.param_pspec("layers.0.mlp.down.w", (100, 50), mesh) \
        == P(None, None)


def test_with_data_axis_densification():
    mesh = _FakeMesh()
    spec = rules.with_data_axis(P("model", None), (32000, 4096), mesh,
                                ("data",))
    assert spec == P("model", "data")


def test_cache_pspec_heads_vs_seq():
    mesh = _FakeMesh()
    # stacked kv cache (L, B, S, H, D): batch over data, heads over model
    spec = rules.cache_pspec("layers.k", (32, 128, 32768, 32, 128), mesh,
                             True, batch_dim=1)
    assert spec == P(None, "data", None, "model", None)
    # batch=1 long-context: sequence context-parallel over data
    spec = rules.cache_pspec("layers.k", (32, 1, 524288, 32, 128), mesh,
                             False, batch_dim=1)
    assert spec[2] == "data"
