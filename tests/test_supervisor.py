"""Streaming supervisor: pipeline backpressure, trace-ring spill/pin
eviction, and end-to-end multi-step bug detection with bisection."""
import os

import numpy as np
import pytest

from repro.core.collector import Trace
from repro.core.thresholds import Thresholds
from repro.supervise.pipeline import AsyncCheckPipeline
from repro.supervise.store import TraceRing, load_trace, save_trace

def _mk_trace(val: float, seed: int = 0) -> Trace:
    rng = np.random.default_rng(seed)
    tr = Trace()
    base = rng.standard_normal((4, 8)).astype(np.float32)
    tr.activations = {"m1/input": base + val, "m1/output": 2 * base + val}
    tr.act_grads = {"m1/input": base - val}
    tr.param_grads = {"m1.w": base * 3 + val}
    tr.main_grads = {"m1.w": base * 3 + val}
    tr.params_post = {"m1.w": base * 5 + val}
    tr.loss = float(val)
    tr.grad_norm = 1.0
    tr.meta["fwd_order"] = ["m1/input", "m1/output"]
    return tr


# ---------------------------------------------------------------------------
# pipeline
# ---------------------------------------------------------------------------

def test_pipeline_backpressure_bounds_in_flight():
    thr = Thresholds(eps=2.0 ** -24)
    pipe = AsyncCheckPipeline(thr, window=2)
    resolved = []
    for k in range(7):
        ref = _mk_trace(0.0, seed=k)
        cand = _mk_trace(0.0 if k != 4 else 1.0, seed=k)   # bug at step 4
        resolved += pipe.submit(k, ref, cand)
        assert pipe.in_flight <= 2          # the backpressure bound
    assert pipe.in_flight == 2
    resolved += pipe.drain()
    assert pipe.in_flight == 0
    assert [c.step for c in resolved] == list(range(7))    # resolve in order
    assert pipe.max_in_flight <= 2
    flagged = [c.step for c in resolved if c.flagged]
    assert flagged == [4]


def test_pipeline_sync_mode_matches_async():
    thr = Thresholds(eps=2.0 ** -24)
    pipe = AsyncCheckPipeline(thr, window=3)
    ref, cand = _mk_trace(0.0), _mk_trace(0.5)
    async_rep = (pipe.submit(1, ref, cand) + pipe.drain())[0].report
    sync_rep = pipe.check_sync(1, ref, cand).report
    assert ([r.flagged for r in async_rep.records]
            == [r.flagged for r in sync_rep.records])
    assert async_rep.localized == sync_rep.localized


def test_pipeline_step0_uses_exact_single_step_thresholds():
    thr = Thresholds(eps=2.0 ** -24)
    pipe = AsyncCheckPipeline(thr, window=1, drift_alpha=0.25)
    assert pipe.scales(0) == {k: 1.0 for k in pipe.kinds}
    s5 = pipe.scales(5)
    from repro.core import canonical as C
    assert s5[C.KIND_ACT] == pipe.kind_mult[C.KIND_ACT] * (1 + 0.25 * 5)
    # the cumulative param comparison stays sharp (drift detector)
    assert s5[C.KIND_PARAM_POST] == 1.0 * (1 + 0.25 * 5)


def test_pipeline_poll_drains_without_is_ready(monkeypatch):
    """jax versions whose arrays lack ``.is_ready`` used to freeze poll()
    forever (nothing resolved until drain); the age fallback now resolves
    entries older than the window in pipeline ticks."""
    import repro.supervise.pipeline as pmod

    def fake_sq_norms(la, lb):
        import numpy as np
        out = np.zeros((len(la), 2), np.float64)
        for i, (a, b) in enumerate(zip(la, lb)):
            d = np.asarray(a, np.float64) - np.asarray(b, np.float64)
            out[i] = [(d * d).sum(), (np.asarray(a, np.float64) ** 2).sum()]
        return out                       # plain ndarray: no .is_ready

    monkeypatch.setattr(pmod, "sq_norms_async", fake_sq_norms)
    pipe = AsyncCheckPipeline(Thresholds(eps=2.0 ** -24), window=2)
    assert pipe.submit(0, _mk_trace(0.0), _mk_trace(0.0)) == []
    # polls age the entry past the window -> it resolves without drain()
    done = []
    for _ in range(4):
        done += pipe.poll()
    assert [c.step for c in done] == [0]
    assert pipe.in_flight == 0


def test_pipeline_swap_thresholds_is_epoch_scoped():
    """Re-estimated thresholds apply to checks at steps >= the swap step;
    earlier steps (late async resolutions, bisection replays) keep the
    schedule they trained under, and margins tighten vs the constants."""
    from repro.core import canonical as C
    from repro.supervise.pipeline import (REESTIMATED_KIND_MULT,
                                          SUPERVISED_KIND_MULT)
    thr0 = Thresholds(eps=2.0 ** -24)
    pipe = AsyncCheckPipeline(thr0, window=2, drift_alpha=0.0,
                              kind_mult=REESTIMATED_KIND_MULT)
    thr1 = Thresholds(eps=2.0 ** -24,
                      per_tensor={C.KIND_ACT: {"m1/input": 0.5}})
    pipe.swap_thresholds(thr1, step=4)
    assert pipe.thresholds_for(3) is thr0
    assert pipe.thresholds_for(4) is thr1
    assert pipe.thresholds_for(9) is thr1
    # per-kind margins under re-estimation never exceed the constants
    for k, m in SUPERVISED_KIND_MULT.items():
        assert REESTIMATED_KIND_MULT[k] <= m
        assert pipe.scales(7)[k] <= m * (1 + pipe.drift_alpha * 7)
    # the sync replay of an old step sees the old (tighter per-tensor) epoch
    old = pipe.check_sync(3, _mk_trace(0.0), _mk_trace(0.0))
    new = pipe.check_sync(5, _mk_trace(0.0), _mk_trace(0.0))
    r_old = [r for r in old.report.records if r.name == "m1/input"
             and r.kind == C.KIND_ACT][0]
    r_new = [r for r in new.report.records if r.name == "m1/input"
             and r.kind == C.KIND_ACT][0]
    assert r_new.threshold > r_old.threshold      # thr1's estimate in force


def test_thresholds_union_only_widens():
    from repro.core import canonical as C
    a = Thresholds(eps=2.0 ** -24,
                   per_tensor={C.KIND_ACT: {"x": 1e-6, "y": 3e-6}})
    b = Thresholds(eps=2.0 ** -24,
                   per_tensor={C.KIND_ACT: {"x": 2e-6},
                               C.KIND_PARAM_GRAD: {"w": 1e-7}})
    u = a.union(b)
    assert u.per_tensor[C.KIND_ACT]["x"] == 2e-6       # max wins
    assert u.per_tensor[C.KIND_ACT]["y"] == 3e-6       # kept
    assert u.per_tensor[C.KIND_PARAM_GRAD]["w"] == 1e-7
    assert a.per_tensor[C.KIND_ACT]["x"] == 1e-6       # inputs untouched


# ---------------------------------------------------------------------------
# trace ring
# ---------------------------------------------------------------------------

def test_ring_eviction_spills_and_prunes(tmp_path):
    ring = TraceRing(window=2, spill_dir=str(tmp_path), spill_keep=3)
    for k in range(8):
        ring.put(k, _mk_trace(float(k)), _mk_trace(float(k) + 0.5))
    assert ring.in_memory == [6, 7]                  # window
    assert len(ring.on_disk) == 3                    # pruned to spill_keep
    assert ring.on_disk == [3, 4, 5]
    ref, cand = ring.get(4)                          # disk round-trip
    np.testing.assert_allclose(ref.activations["m1/input"],
                               _mk_trace(4.0).activations["m1/input"])
    assert ref.meta["fwd_order"] == ["m1/input", "m1/output"]
    with pytest.raises(KeyError):
        ring.get(0)                                  # pruned


def test_ring_pinned_steps_survive(tmp_path):
    ring = TraceRing(window=2, spill_dir=str(tmp_path), spill_keep=1)
    for k in range(4):
        ring.put(k, _mk_trace(float(k)), _mk_trace(float(k)))
    assert ring.pin(1)                               # on disk already
    for k in range(4, 9):
        ring.put(k, _mk_trace(float(k)), _mk_trace(float(k)))
    assert 1 in ring.on_disk                         # pinned survives pruning
    unpinned_disk = [s for s in ring.on_disk if s != 1]
    assert len(unpinned_disk) == 1                   # ring stayed bounded
    ref, _ = ring.get(1)
    assert ref.loss == 1.0


def test_ring_without_spill_drops_unpinned_keeps_pinned():
    ring = TraceRing(window=2, spill_dir=None)
    for k in range(3):
        ring.put(k, _mk_trace(float(k)), _mk_trace(float(k)))
    ring.pin(1)
    for k in range(3, 6):
        ring.put(k, _mk_trace(float(k)), _mk_trace(float(k)))
    assert 1 in ring.in_memory                       # pinned stays live
    assert ring.pin(0) is False                      # dropped: nothing left
    with pytest.raises(KeyError):
        ring.get(2)
    assert set(ring.in_memory) == {1, 4, 5}


def test_checkpoint_keeper_thins_log_spaced(tmp_path):
    import os

    import jax.numpy as jnp

    from repro.supervise.bisect import CheckpointKeeper
    keeper = CheckpointKeeper(str(tmp_path), keep=4)
    state = ({"w": jnp.ones((2,))}, {"m": jnp.zeros((2,))})
    for s in range(0, 36, 4):
        keeper.save(s, state, state)
    assert len(keeper.steps) <= 5                    # bounded, not linear
    assert 0 in keeper.steps and 32 in keeper.steps  # endpoints survive
    for s in keeper.steps:                           # dirs match the index
        assert os.path.isdir(keeper._dir(s))
    on_disk = [d for d in os.listdir(str(tmp_path)) if d.startswith("step_")]
    assert len(on_disk) == len(keeper.steps)         # pruned dirs removed


def test_save_load_trace_roundtrip(tmp_path):
    tr = _mk_trace(0.25)
    save_trace(str(tmp_path / "t"), tr, step=3)
    back = load_trace(str(tmp_path / "t"))
    for f in ("activations", "act_grads", "param_grads", "main_grads",
              "params_post"):
        a, b = getattr(tr, f), getattr(back, f)
        assert list(a) == list(b)
        for name in a:
            np.testing.assert_array_equal(a[name], b[name])
    assert back.loss == tr.loss


# ---------------------------------------------------------------------------
# end-to-end (single device, in-process): clean pass + a W-CP bug
# ---------------------------------------------------------------------------

def _small_setup():
    import dataclasses

    import jax

    from repro.configs.base import get_config
    from repro.models.model import Model
    from repro.optim.adamw import AdamW
    cfg = dataclasses.replace(get_config("gpt-paper").reduced(),
                              n_layers=2, vocab=256, tie_embeddings=True)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params, AdamW(lr=1e-3)


def test_supervisor_clean_run_passes(tmp_path):
    from repro.parallel.api import ParallelConfig
    from repro.supervise import Supervisor, SuperviseConfig
    cfg, model, params, opt = _small_setup()
    sup = Supervisor(model, cfg, ParallelConfig(), opt, params=params,
                     scfg=SuperviseConfig(steps=5, ring_window=2,
                                          work_dir=str(tmp_path)),
                     batch_size=2, seq_len=16)
    res = sup.run()
    assert res.passed, res.summary()
    assert len(res.checks) == 5
    assert res.steps_run == 5
    # ring_window=2 is raised to async_window * check_every + 1 = 3 so a
    # step's trace is still live when its async check resolves
    assert sup.ring.window == 3
    assert sup.ring.in_memory == [2, 3, 4]
    assert sup.ring.on_disk == [0, 1]                # spilled, memory flat
    assert sup.pipe.max_in_flight <= 2


def test_supervisor_periodic_reestimation_clean_run(tmp_path):
    """Re-estimation every R steps: a clean supervised run passes, fresh
    epochs land in the pipeline, and the per-kind margins in force are no
    wider than the constant SUPERVISED_KIND_MULT schedule."""
    from repro.parallel.api import ParallelConfig
    from repro.supervise import (SUPERVISED_KIND_MULT, Supervisor,
                                 SuperviseConfig)
    cfg, model, params, opt = _small_setup()
    sup = Supervisor(model, cfg, ParallelConfig(), opt, params=params,
                     scfg=SuperviseConfig(steps=6, reestimate_every=2,
                                          work_dir=str(tmp_path)),
                     batch_size=2, seq_len=16)
    res = sup.run()
    assert res.passed, res.summary()
    assert res.reestimations == 2                    # steps 2 and 4
    assert len(sup.pipe._epochs) == 3                # step-0 + two swaps
    for k in range(1, 6):
        scales = sup.pipe.scales(k)
        growth = 1 + sup.pipe.drift_alpha * k
        for kind, mult in SUPERVISED_KIND_MULT.items():
            assert scales[kind] <= mult * growth + 1e-12, (k, kind)
    # union-merged epochs only ever widen the per-tensor floors
    thr0, thr_last = sup.pipe._epochs[0][1], sup.pipe._epochs[-1][1]
    for kind, named in thr0.per_tensor.items():
        for name, est in named.items():
            assert thr_last.per_tensor[kind][name] >= est


def test_supervisor_detects_recompute_bug_and_bisects(tmp_path):
    import fnmatch

    from repro.bugs.registry import BUGS
    from repro.parallel.api import ParallelConfig
    from repro.supervise import Supervisor, SuperviseConfig
    cfg, model, params, opt = _small_setup()
    spec = BUGS["ar_stale_recompute"]                # W-CP, no parallelism req
    pcfg = ParallelConfig(bugs=frozenset(["ar_stale_recompute"]))
    sup = Supervisor(model, cfg, pcfg, opt, params=params,
                     scfg=SuperviseConfig(steps=4, work_dir=str(tmp_path)),
                     batch_size=2, seq_len=16)
    res = sup.run()
    assert res.flagged
    assert res.first_bad_step == 0                   # buggy from step 0
    assert res.first_flagged_step in sup.ring.pinned
    loc = res.localized_module or "-"
    assert fnmatch.fnmatchcase(loc, spec.expected_module), (
        loc, spec.expected_module)


# ---------------------------------------------------------------------------
# end-to-end (8 forced host devices, subprocess)
# ---------------------------------------------------------------------------

def _run(code: str, devices: int = 8, timeout: int = 2400) -> str:
    from conftest import run_in_worker
    return run_in_worker(code, devices=devices, timeout=timeout)


PREAMBLE = """
import dataclasses, fnmatch, jax
from repro.bugs.registry import BUGS
from repro.configs.base import get_config
from repro.models.model import Model
from repro.optim.adamw import AdamW
from repro.parallel.api import ParallelConfig
from repro.supervise import Supervisor, SuperviseConfig

cfg = dataclasses.replace(get_config("gpt-paper").reduced(),
                          n_layers=2, vocab=512, tie_embeddings=True)
model = Model(cfg)
params = model.init(jax.random.PRNGKey(0))
"""


@pytest.mark.slow
def test_supervisor_flags_distributed_bugs_with_expected_module():
    out = _run(PREAMBLE + """
for bug in ["tp_wrong_embedding_mask", "dp_wrong_loss_scale",
            "zero_skipped_update"]:
    spec = BUGS[bug]
    req = set(spec.requires)
    pcfg = ParallelConfig(dp=2, tp=2, sp="sp" in req, zero1="zero1" in req,
                          bugs=frozenset([bug]))
    sup = Supervisor(model, cfg, pcfg, AdamW(lr=1e-3), params=params,
                     scfg=SuperviseConfig(steps=3))
    res = sup.run()
    assert res.flagged, bug
    assert res.first_bad_step == 0, (bug, res.first_bad_step)
    loc = res.localized_module or "-"
    ok = (fnmatch.fnmatchcase(loc, spec.expected_module)
          or spec.expected_module == "loss")
    assert ok, (bug, loc, spec.expected_module)
    print("OK", bug, "->", loc)
print("ALL_BUGS_FLAGGED")
""", devices=4)
    assert "ALL_BUGS_FLAGGED" in out


@pytest.mark.slow
def test_supervisor_catches_late_visible_update_bug():
    """zero_skipped_update at a fine-tuning learning rate: the single-step
    check passes, the multi-step supervisor flags the accumulated drift."""
    out = _run(PREAMBLE + """
from repro.core.harness import make_model_runner, ttrace_check
from repro.data.synthetic import make_batch
from repro.parallel.api import make_candidate_runner

LR = 1e-7
pcfg = ParallelConfig(dp=2, tp=2, zero1=True,
                      bugs=frozenset(["zero_skipped_update"]))
opt = AdamW(lr=LR)
one = ttrace_check(
    make_model_runner(model, params, opt, opt.init(params)),
    make_candidate_runner(cfg, pcfg, params, opt, opt.init(params)),
    make_batch(cfg, 4, 32), localize=False)
assert one.passed, "single-step check should miss this at lr=1e-7"

sup = Supervisor(model, cfg, pcfg, AdamW(lr=LR), params=params,
                 scfg=SuperviseConfig(steps=16, check_every=2, ckpt_every=4))
res = sup.run()
assert res.flagged, "supervisor should catch the accumulated drift"
assert res.first_flagged_step >= 1, res.first_flagged_step
assert res.first_bad_step >= 1, res.first_bad_step
print("LATE_CATCH", res.first_flagged_step, res.first_bad_step)
""", devices=4)
    assert "LATE_CATCH" in out
