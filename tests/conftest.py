"""Shared test infrastructure.

The multi-device tests (the real 1F1B pipeline engine, in-process shard_map
candidates) need a multi-device platform INSIDE the main pytest process, and
XLA only honors ``--xla_force_host_platform_device_count`` if it is set
before jax initializes its backends.  conftest is imported before any test
module, so exporting here is early enough for a normal ``pytest`` run; when
the env arrives too late anyway (jax already initialized by an earlier
plugin/session), ``forced_devices`` falls back to spawning a worker process
with the env set — tests that need in-process devices skip with a pointer,
tests that can run code in a worker use ``run_in_worker``.
"""
import os

if "XLA_FLAGS" not in os.environ:                       # noqa: E402
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: multi-device subprocess integration tests")
    config.addinivalue_line(
        "markers", "multidevice: needs >= 4 in-process devices (deselect "
        "with -m 'not multidevice' for a fast tier-1 lane)")


def run_in_worker(code: str, devices: int = 8, timeout: int = 2400) -> str:
    """Run ``code`` in a fresh interpreter with ``devices`` forced host
    devices — the spawned-worker fallback for environments where this
    process's jax initialized before the XLA_FLAGS export."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, timeout=timeout,
                         env=env, cwd=ROOT)
    assert out.returncode == 0, out.stdout + "\n" + out.stderr
    return out.stdout


@pytest.fixture(scope="session")
def forced_devices():
    """Session guarantee of a multi-device in-process platform.

    Returns the live device count; skips (pointing at ``run_in_worker``)
    when jax initialized before the forced-count export could take effect."""
    import jax
    n = len(jax.devices())
    if n < 4:
        pytest.skip(f"only {n} in-process device(s): jax initialized before "
                    f"XLA_FLAGS could force 8 — use conftest.run_in_worker "
                    f"for this test")
    return n


@pytest.fixture(scope="session")
def worker_run():
    """The spawned-worker runner as a fixture (multi-device e2e CLI tests)."""
    return run_in_worker
