"""Threshold estimation (§5) + equivalence checker (§4.4) unit behavior."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.core import canonical as C
from repro.core.checker import compare_traces
from repro.core.collector import Trace
from repro.core.harness import make_model_runner, ttrace_check
from repro.core.thresholds import (MACHINE_EPS, Thresholds,
                                   estimate_thresholds, rel_err)
from repro.data.synthetic import make_batch
from repro.models.model import Model
from repro.optim.adamw import AdamW


def test_rel_err_frobenius():
    a = np.ones((4, 4), np.float32)
    b = a.copy()
    b[0, 0] = 2.0
    assert abs(rel_err(a, b) - 0.25) < 1e-6
    assert rel_err(a, a) == 0.0


def test_threshold_floor_and_margin():
    thr = Thresholds(eps=1e-7, margin=8.0, floor_mult=4.0,
                     per_tensor={"activation": {"x": 1e-5}})
    assert thr.threshold("activation", "x") == pytest.approx(8e-5)
    assert thr.threshold("activation", "unknown") == pytest.approx(3.2e-6)
    # param_post uses the wider kind margin
    assert thr.threshold(C.KIND_PARAM_POST, "unknown") == pytest.approx(
        64 * 4e-7)


def _mk_trace(vals: dict) -> Trace:
    t = Trace()
    t.activations = {k: np.asarray(v, np.float32) for k, v in vals.items()}
    t.meta["fwd_order"] = list(vals)
    return t


def test_compare_and_propagation_localization():
    ref = _mk_trace({"embedding/output": [1.0, 1.0],
                     "layers.0.mlp/output": [2.0, 2.0],
                     "layers.1.mlp/output": [3.0, 3.0]})
    cand = _mk_trace({"embedding/output": [1.0, 1.0],
                      "layers.0.mlp/output": [2.5, 2.0],   # first divergence
                      "layers.1.mlp/output": [9.0, 3.0]})
    thr = Thresholds(eps=1e-7)
    rep = compare_traces(ref, cand, thr, kinds=(C.KIND_ACT,))
    assert not rep.passed
    assert rep.localized == "layers.0.mlp"
    assert rep.localization_mode == "propagation"


def test_shape_mismatch_flagged():
    ref = _mk_trace({"a/output": np.ones((2, 2))})
    cand = _mk_trace({"a/output": np.ones((2, 3))})
    rep = compare_traces(ref, cand, Thresholds(eps=1e-7),
                         kinds=(C.KIND_ACT,))
    assert rep.flagged and "shape" in rep.flagged[0].note


def test_missing_tensor_reported():
    ref = _mk_trace({"a/output": np.ones(2), "b/output": np.ones(2)})
    cand = _mk_trace({"a/output": np.ones(2)})
    rep = compare_traces(ref, cand, Thresholds(eps=1e-7),
                         kinds=(C.KIND_ACT,))
    assert rep.missing


def test_estimate_thresholds_scale_with_eps():
    """Bigger perturbation -> (roughly) proportionally bigger estimates."""
    cfg = dataclasses.replace(get_config("gpt-paper").reduced(), n_layers=2,
                              vocab=256)
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    runner = make_model_runner(m, params)
    batch = make_batch(cfg, 2, 16)
    t1, _ = estimate_thresholds(runner, batch, 1e-6)
    t2, _ = estimate_thresholds(runner, batch, 1e-4)
    k = "final_norm_out"
    r = t2.per_tensor["activation"][k] / max(t1.per_tensor["activation"][k],
                                             1e-30)
    assert 10 < r < 1000    # ~100x, allowing nonlinearity


def test_ttrace_check_identical_candidate_passes():
    cfg = dataclasses.replace(get_config("gpt-paper").reduced(), n_layers=2,
                              vocab=256)
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(1))
    opt = AdamW(lr=1e-3)
    st = opt.init(params)
    batch = make_batch(cfg, 2, 16)
    res = ttrace_check(make_model_runner(m, params, opt, st),
                       make_model_runner(m, params, opt, st), batch,
                       localize=False)
    assert res.passed


def test_ttrace_detects_single_device_bug_and_localizes():
    cfg = dataclasses.replace(get_config("gpt-paper").reduced(), n_layers=2,
                              vocab=256)
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(1))
    batch = make_batch(cfg, 2, 16)
    bad = jax.tree.map(lambda x: x, params)
    bad["layers"][1]["mlp"]["down"]["w"] = \
        bad["layers"][1]["mlp"]["down"]["w"] * 1.01
    res = ttrace_check(make_model_runner(m, params),
                       make_model_runner(m, bad), batch, localize=True)
    assert not res.passed
    assert "layers.1.mlp" in res.localized_module


# ---------------------------------------------------------------------------
# pair-collection unification (ISSUE 4): trace_fn_pair and the supervised
# re-estimator share ONE build-once vmapped pair collection — thresholds
# from the one-shot fused path and from make_pair_estimator must be
# identical, not merely close
# ---------------------------------------------------------------------------

def _float_net():
    import jax.nn
    import jax.numpy as jnp
    from repro.core.tap import ensure_ctx

    def loss_call(params, batch, ctx):
        ctx = ensure_ctx(ctx)
        h = batch["x"]
        for i, p in enumerate(params["layers"]):
            with ctx.scope(f"layers.{i}.mlp"):
                h = ctx.tap("input", h)
                h = jax.nn.gelu(h @ p["w"])
                h = ctx.tap("output", h)
        return (h.astype(jnp.float32) ** 2).mean()

    return loss_call


def test_pair_estimator_matches_one_shot_fused_estimation():
    """Float-input path: estimate_thresholds' fused pair run (trace_fn_pair)
    and make_pair_estimator at step 0 perturb with the same seeds and now
    run the same compiled collection — their per-tensor estimates must
    agree exactly."""
    import numpy as np

    from repro.core.collector import trace_fn_pair, trace_fn_step
    from repro.core.thresholds import make_pair_estimator
    key = jax.random.PRNGKey(0)
    params = {"layers": [
        {"w": 0.2 * jax.random.normal(jax.random.fold_in(key, i), (32, 32))}
        for i in range(2)]}
    batch = {"x": np.asarray(jax.random.normal(jax.random.PRNGKey(9),
                                               (4, 32)))}
    loss_call = _float_net()
    opt = AdamW(lr=1e-3)
    st = opt.init(params)

    def runner(b, rewrites=None):
        tr, _, _ = trace_fn_step(loss_call, params, b, opt=opt,
                                 opt_state=st, rewrites=rewrites)
        return tr
    runner.pair = lambda b2: trace_fn_pair(loss_call, params, b2, opt=opt,
                                           opt_state=st)

    eps = 1e-6
    thr_fused, _ = estimate_thresholds(runner, batch, eps, seed=3)
    est = make_pair_estimator(loss_call, opt, params, batch, eps, seed=3)
    thr_live = est(params, st, batch, step=0)
    assert set(thr_fused.per_tensor) == set(thr_live.per_tensor)
    for kind, named in thr_fused.per_tensor.items():
        assert set(named) == set(thr_live.per_tensor[kind]), kind
        for name, est_val in named.items():
            live = thr_live.per_tensor[kind][name]
            assert live == pytest.approx(est_val, rel=1e-9, abs=1e-30), (
                kind, name)


def test_pair_estimator_token_mode_still_deterministic():
    """Token-input path (per-row embedding-perturbation rewrite folded into
    the shared collector): two independently built estimators agree
    exactly, and the estimates are non-trivial."""
    from repro.core.thresholds import make_pair_estimator
    cfg = dataclasses.replace(get_config("gpt-paper").reduced(), n_layers=2,
                              vocab=256, tie_embeddings=True)
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, 2, 16)
    opt = AdamW(lr=1e-3)
    st = opt.init(params)

    def loss_call(p, b, ctx):
        return m.loss(p, b, ctx=ctx)[0]

    t1 = make_pair_estimator(loss_call, opt, params, batch, 1e-6,
                             seed=1)(params, st, batch, step=2)
    t2 = make_pair_estimator(loss_call, opt, params, batch, 1e-6,
                             seed=1)(params, st, batch, step=2)
    assert t1.per_tensor == t2.per_tensor
    assert t1.per_tensor["activation"]["final_norm_out"] > 0
