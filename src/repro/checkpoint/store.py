"""Sharded checkpointing: save/restore params + optimizer state as .npz
shards with a JSON manifest.

Layout metadata records each leaf's path, shape, dtype and which shard file
holds it, so restores work regardless of the host count that wrote the
checkpoint.  Leaves larger than ``shard_bytes`` are split along axis 0 into
multiple entries (the single-controller analogue of per-rank checkpoint
shards).
"""
from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import ml_dtypes  # registers bfloat16/fp8 with numpy  # noqa: F401
import numpy as np

from repro.core.collector import flatten_named, unflatten_named

MANIFEST = "manifest.json"


def save_checkpoint(path: str, tree, *, step: int = 0,
                    shard_bytes: int = 512 << 20, extra: dict | None = None):
    os.makedirs(path, exist_ok=True)
    named = flatten_named(tree)
    manifest = {"step": step, "leaves": {}, "extra": extra or {}}
    shard_id, cur_bytes, cur = 0, 0, {}

    def flush():
        nonlocal shard_id, cur_bytes, cur
        if cur:
            np.savez(os.path.join(path, f"shard_{shard_id:05d}.npz"), **cur)
            shard_id += 1
            cur_bytes, cur = 0, {}

    for name, leaf in named.items():
        arr = np.asarray(leaf)
        n = arr.nbytes
        pieces = 1
        if n > shard_bytes and arr.ndim >= 1 and arr.shape[0] > 1:
            pieces = min(arr.shape[0], -(-n // shard_bytes))
        entry = {"shape": list(arr.shape), "dtype": str(arr.dtype),
                 "pieces": []}
        chunks = ([arr] if arr.ndim == 0
                  else np.array_split(arr, pieces, axis=0))
        for i, piece in enumerate(chunks):
            key = f"{name}::{i}"
            if cur_bytes + piece.nbytes > shard_bytes:
                flush()
            # store exotic dtypes (bf16, fp8) as raw bytes; dtype is in the
            # manifest and restored on load
            cur[key] = piece.view(np.uint8) if piece.dtype.kind == "V" or \
                piece.dtype.name not in ("float64", "float32", "float16",
                                         "int64", "int32", "int16", "int8",
                                         "uint8", "uint16", "uint32",
                                         "uint64", "bool") else piece
            cur_bytes += piece.nbytes
            entry["pieces"].append({"file": f"shard_{shard_id:05d}.npz",
                                    "key": key})
        manifest["leaves"][name] = entry
    flush()
    with open(os.path.join(path, MANIFEST), "w") as f:
        json.dump(manifest, f, indent=1)
    return manifest


def load_checkpoint(path: str, template):
    with open(os.path.join(path, MANIFEST)) as f:
        manifest = json.load(f)
    files: dict[str, np.lib.npyio.NpzFile] = {}

    def npz(fn):
        if fn not in files:
            files[fn] = np.load(os.path.join(path, fn))
        return files[fn]

    named = {}
    for name, entry in manifest["leaves"].items():
        pieces = [npz(p["file"])[p["key"]] for p in entry["pieces"]]
        arr = pieces[0] if len(pieces) == 1 else np.concatenate(pieces, 0)
        want = np.dtype(entry["dtype"])
        if arr.dtype != want:
            if arr.dtype == np.uint8:      # raw-byte exotic dtype
                arr = arr.reshape(-1).view(want).reshape(entry["shape"])
            else:
                arr = arr.astype(want)
        named[name] = jnp.asarray(arr)
    tree = unflatten_named(named, template)
    return tree, manifest["step"], manifest.get("extra", {})
