"""Sharded checkpointing: save/restore params + optimizer state as .npz
shards with a JSON manifest.

Layout metadata records each leaf's path, shape, dtype and which shard file
holds it, so restores work regardless of the host count that wrote the
checkpoint.  Leaves larger than ``shard_bytes`` are split along axis 0 into
multiple entries (the single-controller analogue of per-rank checkpoint
shards).

Restores are round-trip exact for every dtype the training stack uses:
exotic dtypes (bf16, fp8) are stored as raw bytes and re-viewed on load, and
``load_checkpoint`` re-establishes each leaf's device placement from the
template tree — a leaf restored against a sharded ``jax.Array`` template
comes back on the same mesh with the same ``NamedSharding``, not as a
host-default array (the supervisor's bisection replay depends on this
being exact).

Every piece carries a CRC32 in the manifest, verified at load: a
truncated shard or bit-flipped payload raises ``ChecksumError`` instead of
silently restoring garbage — the supervisor's bisection then falls back to
an earlier checkpoint and the trace ring treats the spilled step as lost
evidence, both loud.  Manifests written before checksums load unchecked.
"""
from __future__ import annotations

import json
import os
import zipfile
import zlib

import jax
import jax.numpy as jnp
import ml_dtypes  # registers bfloat16/fp8 with numpy  # noqa: F401
import numpy as np

from repro.core.collector import flatten_named, unflatten_named

MANIFEST = "manifest.json"


class ChecksumError(RuntimeError):
    """A checkpoint/spill payload failed CRC verification at load."""

# numpy-native dtypes that np.savez round-trips by itself; anything else
# (bf16, fp8, ...) is stored as raw bytes and re-viewed on load
_NATIVE_DTYPES = ("float64", "float32", "float16", "int64", "int32", "int16",
                  "int8", "uint8", "uint16", "uint32", "uint64", "bool")


def _as_bytes(piece: np.ndarray) -> np.ndarray:
    """View an exotic-dtype piece as uint8 (0-d safe: reshape first)."""
    return np.ascontiguousarray(piece).reshape(-1).view(np.uint8)


def save_checkpoint(path: str, tree, *, step: int = 0,
                    shard_bytes: int = 512 << 20, extra: dict | None = None,
                    container: str = "npz"):
    """``container="npz"`` (default) writes numpy .npz shards;
    ``container="raw"`` writes flat binary shards with manifest
    byte-offsets — ~3x faster (no zip framing, no CRC pass), used by the
    supervisor's trace spill where serialization rides the hot loop's
    background writer.  Both containers share the manifest and loader."""
    if container not in ("npz", "raw"):
        raise ValueError(f"unknown checkpoint container {container!r}")
    os.makedirs(path, exist_ok=True)
    named = flatten_named(tree)
    manifest = {"step": step, "leaves": {}, "extra": extra or {}}
    shard_id, cur_bytes, cur = 0, 0, {}
    raw_f = None

    def shard_name():
        return f"shard_{shard_id:05d}." + container

    def flush():
        nonlocal shard_id, cur_bytes, cur, raw_f
        if cur:
            np.savez(os.path.join(path, shard_name()), **cur)
            shard_id += 1
            cur_bytes, cur = 0, {}
        if raw_f is not None:
            raw_f.close()
            raw_f = None
            shard_id += 1
            cur_bytes = 0

    for name, leaf in named.items():
        arr = np.asarray(leaf)
        n = arr.nbytes
        pieces = 1
        if n > shard_bytes and arr.ndim >= 1 and arr.shape[0] > 1:
            pieces = min(arr.shape[0], -(-n // shard_bytes))
        entry = {"shape": list(arr.shape), "dtype": str(arr.dtype),
                 "pieces": []}
        chunks = ([arr] if arr.ndim == 0
                  else np.array_split(arr, pieces, axis=0))
        exotic = arr.dtype.kind == "V" or arr.dtype.name not in _NATIVE_DTYPES
        for i, piece in enumerate(chunks):
            if cur_bytes + piece.nbytes > shard_bytes:
                flush()
            if container == "raw":
                if raw_f is None:
                    raw_f = open(os.path.join(path, shard_name()), "wb")
                data = _as_bytes(piece)
                entry["pieces"].append({"file": shard_name(),
                                        "offset": raw_f.tell(),
                                        "nbytes": int(data.nbytes),
                                        "crc": zlib.crc32(data)})
                raw_f.write(memoryview(data))
            else:
                key = f"{name}::{i}"
                cur[key] = _as_bytes(piece) if exotic else piece
                entry["pieces"].append({"file": shard_name(), "key": key,
                                        "crc": zlib.crc32(
                                            _as_bytes(piece))})
            cur_bytes += piece.nbytes
        manifest["leaves"][name] = entry
    flush()
    with open(os.path.join(path, MANIFEST), "w") as f:
        json.dump(manifest, f, indent=1)
    return manifest


def load_checkpoint_named(path: str) -> tuple[dict[str, np.ndarray], int,
                                              dict]:
    """Template-free restore: ``(flat {name: numpy leaf}, step, extra)``.

    Leaves come back as host numpy with the manifest dtype (bf16/fp8 raw
    bytes re-viewed); placement is the caller's concern — ``load_checkpoint``
    layers template-driven ``jax.Array`` placement on top of this.

    Pieces whose manifest entry carries a ``crc`` are verified; a mismatch,
    a truncated shard, or an unreadable container raises ``ChecksumError``.
    """
    try:
        with open(os.path.join(path, MANIFEST)) as f:
            manifest = json.load(f)
    except (json.JSONDecodeError, UnicodeDecodeError) as e:
        raise ChecksumError(f"unreadable manifest at {path}: {e}") from e
    files: dict[str, object] = {}

    def npz(fn):
        if fn not in files:
            files[fn] = np.load(os.path.join(path, fn))
        return files[fn]

    def piece_of(p):
        try:
            if "offset" in p:       # raw container: byte-offset slice
                if p["file"] not in files:
                    with open(os.path.join(path, p["file"]), "rb") as f:
                        files[p["file"]] = f.read()
                buf = files[p["file"]]
                piece = np.frombuffer(buf, np.uint8,
                                      count=p["nbytes"], offset=p["offset"])
            else:
                piece = npz(p["file"])[p["key"]]
        except (ValueError, OSError, KeyError, zipfile.BadZipFile) as e:
            # truncated raw shard (frombuffer out of range), torn npz zip,
            # missing key — all the same verdict: the payload is not the
            # one the manifest describes
            raise ChecksumError(
                f"unreadable piece {p.get('key') or p.get('offset')} of "
                f"{p['file']} at {path}: {e}") from e
        if "crc" in p and zlib.crc32(_as_bytes(piece)) != p["crc"]:
            raise ChecksumError(
                f"CRC mismatch in {p['file']} at {path} "
                f"(piece {p.get('key') or p.get('offset')})")
        return piece

    named = {}
    for name, entry in manifest["leaves"].items():
        pieces = [piece_of(p) for p in entry["pieces"]]
        want = np.dtype(entry["dtype"])
        if pieces[0].dtype == np.uint8 and want != np.uint8:
            # raw-byte exotic dtype: re-view each piece, then stitch
            pieces = [p.reshape(-1).view(want) for p in pieces]
            arr = (pieces[0] if len(pieces) == 1
                   else np.concatenate(pieces)).reshape(entry["shape"])
        else:
            arr = (pieces[0] if len(pieces) == 1
                   else np.concatenate(pieces, 0))
            if arr.dtype != want:
                arr = arr.astype(want)
            arr = arr.reshape(entry["shape"])
        named[name] = arr
    return named, manifest["step"], manifest.get("extra", {})


def _place_like(arr: np.ndarray, template_leaf):
    """Re-establish the template leaf's device placement and dtype class.

    * template is a ``jax.Array``: ``device_put`` onto its sharding (mesh
      placement preserved for distributed state) with the CHECKPOINT dtype —
      the checkpoint is the source of truth for values/dtype, the template
      for placement;
    * template is anything else (numpy, python scalar): plain ``jnp.asarray``.
    """
    sharding = getattr(template_leaf, "sharding", None)
    if sharding is not None:
        devs = getattr(sharding, "device_set", None) or set()
        default = jax.devices()[0]
        if len(devs) == 1 and next(iter(devs)) == default:
            # plain default-device template: restore UNcommitted (like a
            # fresh jnp.asarray) so downstream jits remain free to place it
            # — committing here would pin mixed-device computations
            return jnp.asarray(arr)
        return jax.device_put(jnp.asarray(arr), sharding)
    return jnp.asarray(arr)


def load_checkpoint(path: str, template):
    """Restore a pytree saved by ``save_checkpoint``.

    Every leaf comes back as a ``jax.Array`` with the checkpointed dtype and
    the TEMPLATE leaf's device placement/sharding — round-trip exact for
    bf16/fp8 leaves and for sharded distributed state.
    """
    named, step, extra = load_checkpoint_named(path)
    tmpl_named = flatten_named(template)
    placed = {name: _place_like(arr, tmpl_named.get(name))
              for name, arr in named.items()}
    tree = unflatten_named(placed, template)
    return tree, step, extra
