"""Expected-FP-round-off-error estimation (paper §5).

The threshold for "is this difference a bug or just floating point?" is
estimated empirically, exactly as §5.2 prescribes: run the reference twice —
once on X and once on X + dX with ||dX|| ~= eps_mch * ||X|| — and record the
induced relative Frobenius error of every traced tensor.  Under the layer
smoothness assumptions (Thm 5.1-5.3) the induced differences track the
accumulated round-off of any *reasonable* FP implementation, so a candidate
whose differences are far above them (paper observes ~100x for real bugs) is
flagged.

For token (integer) inputs the perturbation is applied at the first float
tensor on the differentiation path — the embedding output — via the rewrite
mechanism; for audio/VLM the float frontend features are perturbed directly.
Float-input models additionally take the FUSED estimation path: the base and
perturbed batches are stacked on a leading axis and collected in one vmapped
compiled call (collector.trace_pair_step) instead of two serial jit
round-trips.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core import canonical as C
from repro.core.collector import Trace
from repro.core.generator import perturb
from repro.core.relerr_engine import batched_rel_err, rel_err_np

MACHINE_EPS = {
    "float32": 2.0 ** -24,
    "bfloat16": 2.0 ** -8,
    "float16": 2.0 ** -11,
    # fp8 recipes accumulate in >=bf16 (paper §6.7): thresholds are expressed
    # in bf16 epsilons, perturbations injected at bf16 magnitude.
    "float8_e4m3fn": 2.0 ** -8,
}


def rel_err(a: np.ndarray, b: np.ndarray) -> float:
    """Relative Frobenius error ||a-b|| / ||a|| (paper §2.2) for one pair.

    Section-scale comparisons go through relerr_engine.batched_rel_err,
    which picks the device-resident batched path by backend/size; this
    per-pair float64 form stays as the reference semantic.
    """
    return rel_err_np(a, b)


@dataclass
class Thresholds:
    eps: float
    margin: float = 8.0
    floor_mult: float = 4.0
    per_tensor: dict[str, dict[str, float]] = field(default_factory=dict)
    # per kind: {name: estimated FP rel err}

    # Post-step parameters pass through Adam's elementwise m/sqrt(v)
    # normalization, which amplifies *uncorrelated* reduction-order noise
    # more than the correlated perturbation used for estimation; a wider
    # margin absorbs that (bug-induced errors are ~100x above, Fig 8).
    kind_margins = {C.KIND_PARAM_POST: 64.0}

    def threshold(self, kind: str, name: str) -> float:
        est = self.per_tensor.get(kind, {}).get(name, 0.0)
        margin = self.kind_margins.get(kind, self.margin)
        return margin * max(est, self.floor_mult * self.eps)


def _diff_sections(t1: Trace, t2: Trace) -> dict[str, dict[str, float]]:
    out = {}
    for kind in (C.KIND_ACT, C.KIND_ACT_GRAD, C.KIND_PARAM_GRAD,
                 C.KIND_MAIN_GRAD, C.KIND_PARAM_POST):
        out[kind] = batched_rel_err(t1.section(kind), t2.section(kind))
    return out


def _float_keys(batch: dict) -> list[str]:
    return [k for k, v in batch.items()
            if np.issubdtype(np.asarray(v).dtype, np.floating)
            and k != "loss_mask"]


def perturbed_batch_or_rewrites(batch: dict, base_trace: Trace,
                                eps: float, seed: int = 0):
    """Returns (batch', rewrites').  Float model inputs are perturbed in the
    batch; token-only models are perturbed at the embedding output."""
    float_keys = _float_keys(batch)
    if float_keys:
        b2 = dict(batch)
        for i, k in enumerate(float_keys):
            b2[k] = perturb(np.asarray(batch[k]), eps, seed=seed + i)
        return b2, None
    emb = "embedding/output"
    assert emb in base_trace.activations, (
        "no float inputs and no embedding/output tap to perturb")
    rew = {emb: perturb(base_trace.activations[emb], eps, seed=seed)}
    return batch, rew


def estimate_thresholds(run_trace, batch: dict, eps: float,
                        margin: float = 8.0, seed: int = 0) -> tuple[
                            Thresholds, Trace]:
    """``run_trace(batch, rewrites) -> Trace`` runs the REFERENCE.

    Returns (thresholds, base_reference_trace) — the base trace is reused as
    the reference side of the differential test, so threshold estimation
    costs exactly one extra iteration (paper §3 step 1).

    If the runner exposes ``.pair`` (collector.trace_pair_step underneath)
    and the batch has float inputs, base and perturbed runs are stacked and
    collected in one compiled call; otherwise the two runs stay serial (the
    token-input perturbation needs the base trace's embedding output before
    the perturbed run can start).
    """
    t1 = t2 = None
    pair = getattr(run_trace, "pair", None)
    if pair is not None and _float_keys(batch):
        b2, _ = perturbed_batch_or_rewrites(batch, None, eps, seed)
        stacked = {k: np.stack([np.asarray(batch[k]), np.asarray(b2[k])])
                   for k in batch}
        try:
            t1, t2 = pair(stacked)
        except Exception as e:      # model not vmappable -> serial fallback
            import warnings
            warnings.warn(
                "fused threshold estimation failed "
                f"({type(e).__name__}: {e}); falling back to two serial "
                "reference runs", RuntimeWarning)
            t1 = t2 = None
    if t1 is None:
        t1 = run_trace(batch, None)
        b2, rew = perturbed_batch_or_rewrites(batch, t1, eps, seed)
        t2 = run_trace(b2, rew)
    thr = Thresholds(eps=eps, margin=margin, per_tensor=_diff_sections(t1, t2))
    return thr, t1
