"""Expected-FP-round-off-error estimation (paper §5).

The threshold for "is this difference a bug or just floating point?" is
estimated empirically, exactly as §5.2 prescribes: run the reference twice —
once on X and once on X + dX with ||dX|| ~= eps_mch * ||X|| — and record the
induced relative Frobenius error of every traced tensor.  Under the layer
smoothness assumptions (Thm 5.1-5.3) the induced differences track the
accumulated round-off of any *reasonable* FP implementation, so a candidate
whose differences are far above them (paper observes ~100x for real bugs) is
flagged.

For token (integer) inputs the perturbation is applied at the first float
tensor on the differentiation path — the embedding output — via the rewrite
mechanism; for audio/VLM the float frontend features are perturbed directly.
Float-input models additionally take the FUSED estimation path: the base and
perturbed batches are stacked on a leading axis and collected in one vmapped
compiled call (collector.trace_pair_step) instead of two serial jit
round-trips.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core import canonical as C
from repro.core.collector import Trace
from repro.core.generator import perturb
from repro.core.relerr_engine import rel_err_np

MACHINE_EPS = {
    "float32": 2.0 ** -24,
    "bfloat16": 2.0 ** -8,
    "float16": 2.0 ** -11,
    # fp8 recipes accumulate in >=bf16 (paper §6.7): thresholds are expressed
    # in bf16 epsilons, perturbations injected at bf16 magnitude.
    "float8_e4m3fn": 2.0 ** -8,
}


def rel_err(a: np.ndarray, b: np.ndarray) -> float:
    """Relative Frobenius error ||a-b|| / ||a|| (paper §2.2) for one pair.

    Section-scale comparisons go through relerr_engine.batched_rel_err,
    which picks the device-resident batched path by backend/size; this
    per-pair float64 form stays as the reference semantic.
    """
    return rel_err_np(a, b)


@dataclass
class Thresholds:
    eps: float
    margin: float = 8.0
    floor_mult: float = 4.0
    per_tensor: dict[str, dict[str, float]] = field(default_factory=dict)
    # per kind: {name: estimated FP rel err}

    # Post-step parameters pass through Adam's elementwise m/sqrt(v)
    # normalization, which amplifies *uncorrelated* reduction-order noise
    # more than the correlated perturbation used for estimation; a wider
    # margin absorbs that (bug-induced errors are ~100x above, Fig 8).
    kind_margins = {C.KIND_PARAM_POST: 64.0}

    def threshold(self, kind: str, name: str) -> float:
        est = self.per_tensor.get(kind, {}).get(name, 0.0)
        margin = self.kind_margins.get(kind, self.margin)
        return margin * max(est, self.floor_mult * self.eps)

    def union(self, other: "Thresholds") -> "Thresholds":
        """Elementwise-max merge of two estimates (same eps/margin).

        Periodic re-estimation unions each fresh live-batch estimate into
        the running thresholds: per-tensor floors only ever widen, so a
        batch with unusually low FP noise can never shrink a threshold
        below what an earlier batch already proved reachable."""
        per = {k: dict(v) for k, v in self.per_tensor.items()}
        for kind, named in other.per_tensor.items():
            d = per.setdefault(kind, {})
            for n, e in named.items():
                d[n] = max(d.get(n, 0.0), e)
        return Thresholds(eps=self.eps, margin=self.margin,
                          floor_mult=self.floor_mult, per_tensor=per)


def diff_sections_async(t1: Trace, t2: Trace):
    """Dispatch the per-kind pair reductions of two traces on DEVICE and
    return ``resolve() -> {kind: {name: rel_err}}`` (with ``resolve.ready()``
    probing the device futures).

    This is the single reduction path of threshold estimation: the one-shot
    ``estimate_thresholds`` resolves immediately, the supervised loop's
    periodic re-estimator holds the resolve as an in-flight epoch — both see
    bit-identical estimates because the dispatched computation is the same.
    """
    from repro.core.relerr_engine import _to_rel_err, sq_norms_async
    pend = []
    for kind in (C.KIND_ACT, C.KIND_ACT_GRAD, C.KIND_PARAM_GRAD,
                 C.KIND_MAIN_GRAD, C.KIND_PARAM_POST):
        s1, s2 = t1.section(kind), t2.section(kind)
        names = [n for n in s1 if n in s2]
        dev = sq_norms_async([s1.raw(n) for n in names],
                             [s2.raw(n) for n in names])
        pend.append((kind, names, dev))

    def resolve() -> dict[str, dict[str, float]]:
        out = {}
        for kind, names, dev in pend:
            errs = _to_rel_err(np.asarray(dev, np.float64))
            out[kind] = {n: float(e) for n, e in zip(names, errs)}
        return out

    def ready() -> bool:
        for _, _, dev in pend:
            probe = getattr(dev, "is_ready", None)
            if probe is not None and not probe():
                return False
        return True

    resolve.ready = ready
    return resolve


def _diff_sections(t1: Trace, t2: Trace) -> dict[str, dict[str, float]]:
    return diff_sections_async(t1, t2)()


def _float_keys(batch: dict) -> list[str]:
    return [k for k, v in batch.items()
            if np.issubdtype(np.asarray(v).dtype, np.floating)
            and k != "loss_mask"]


def perturbed_batch_or_rewrites(batch: dict, base_trace: Trace,
                                eps: float, seed: int = 0):
    """Returns (batch', rewrites').  Float model inputs are perturbed in the
    batch; token-only models are perturbed at the embedding output."""
    float_keys = _float_keys(batch)
    if float_keys:
        b2 = dict(batch)
        for i, k in enumerate(float_keys):
            b2[k] = perturb(np.asarray(batch[k]), eps, seed=seed + i)
        return b2, None
    emb = "embedding/output"
    assert emb in base_trace.activations, (
        "no float inputs and no embedding/output tap to perturb")
    rew = {emb: perturb(base_trace.activations[emb], eps, seed=seed)}
    return batch, rew


def estimate_thresholds(run_trace, batch: dict, eps: float,
                        margin: float = 8.0, seed: int = 0) -> tuple[
                            Thresholds, Trace]:
    """``run_trace(batch, rewrites) -> Trace`` runs the REFERENCE.

    Returns (thresholds, base_reference_trace) — the base trace is reused as
    the reference side of the differential test, so threshold estimation
    costs exactly one extra iteration (paper §3 step 1).

    If the runner exposes ``.pair`` (collector.trace_pair_step underneath)
    and the batch has float inputs, base and perturbed runs are stacked and
    collected in one compiled call; otherwise the two runs stay serial (the
    token-input perturbation needs the base trace's embedding output before
    the perturbed run can start).
    """
    t1 = t2 = None
    pair = getattr(run_trace, "pair", None)
    if pair is not None and _float_keys(batch):
        b2, _ = perturbed_batch_or_rewrites(batch, None, eps, seed)
        stacked = {k: np.stack([np.asarray(batch[k]), np.asarray(b2[k])])
                   for k in batch}
        try:
            t1, t2 = pair(stacked)
        except Exception as e:      # model not vmappable -> serial fallback
            import warnings
            warnings.warn(
                "fused threshold estimation failed "
                f"({type(e).__name__}: {e}); falling back to two serial "
                "reference runs", RuntimeWarning)
            t1 = t2 = None
    if t1 is None:
        t1 = run_trace(batch, None)
        b2, rew = perturbed_batch_or_rewrites(batch, t1, eps, seed)
        t2 = run_trace(b2, rew)
    thr = Thresholds(eps=eps, margin=margin, per_tensor=_diff_sections(t1, t2))
    return thr, t1


# ---------------------------------------------------------------------------
# Once-compiled fused pair estimator (periodic re-estimation, paper §5 live)
# ---------------------------------------------------------------------------

_EMB_TAP = "embedding/output"


def make_pair_estimator(loss_call, opt, params, batch, eps: float,
                        margin: float = 8.0, seed: int = 0, device=None):
    """Build ``estimate(params, opt_state, batch) -> Thresholds`` compiled
    exactly once — the supervised loop's periodic threshold RE-estimation.

    ``estimate.submit(params, opt_state, batch, step)`` is the ASYNC form:
    it dispatches the pair collection and the per-kind reductions on device
    (under ``device`` when given — the supervisor's reference device set)
    and returns ``resolve() -> Thresholds`` with ``resolve.ready()``; the
    synchronous ``estimate`` is exactly ``submit(...)()``, so overlapped
    and lockstep re-estimation produce bit-identical thresholds.

    The pair collection itself is ``collector.make_pair_collector`` — the
    same build-once vmapped base+perturbed run ``trace_fn_pair`` (and with
    it the one-shot fused estimation path) uses, so the two paths cannot
    drift.  Float model inputs are perturbed per-row in the stacked batch;
    token-only models fold the embedding-output perturbation INTO the
    stacked run via a per-row callable rewrite
    ``x + flag * eps * ||x|| * d/||d||`` (flag 0 on the base row) — the
    fused path the serial estimator cannot take because the one-shot
    rewrite needs the base trace first.
    """
    import jax
    import jax.numpy as jnp

    from repro.core.collector import make_pair_collector

    batch_t = {k: jnp.asarray(v) for k, v in batch.items()}
    float_keys = _float_keys(batch_t)
    token_mode = not float_keys
    base_key = jax.random.PRNGKey(seed ^ 0x5EED)

    row_rewrite = None
    if token_mode:
        def row_rewrite(flag, step_k):
            def perturb_tap(x):
                # directional eps-noise gated by the row flag; matches
                # generator.perturb semantics (||dX|| = eps * ||X||).
                # The direction varies per re-estimation (step folded
                # into the key, like the float path's per-step seed) so
                # the union explores new directions each epoch.
                d = jax.random.normal(jax.random.fold_in(base_key, step_k),
                                      x.shape, jnp.float32)
                nx = jnp.sqrt(jnp.sum(jnp.square(x.astype(jnp.float32))))
                nd = jnp.maximum(jnp.sqrt(jnp.sum(jnp.square(d))), 1e-30)
                return x.astype(jnp.float32) + flag * (eps * nx / nd) * d
            return {_EMB_TAP: perturb_tap}

    collect = make_pair_collector(loss_call, opt, params, batch_t,
                                  row_rewrite=row_rewrite, device=device)
    if token_mode and _EMB_TAP not in collect.shapes:
        raise ValueError("no float inputs and no embedding/output tap — "
                         "cannot build a fused pair estimator")

    def submit(p, st, live_batch, step: int = 0):
        if token_mode:
            b2 = {k: jnp.stack([jnp.asarray(v)] * 2)
                  for k, v in live_batch.items()}
        else:
            b2 = {}
            for i, k in enumerate(live_batch):
                base = np.asarray(live_batch[k])
                pert = (perturb(base, eps, seed=seed + step * 131 + i)
                        if k in float_keys else base)
                b2[k] = jnp.stack([jnp.asarray(base), jnp.asarray(pert)])
        t0, t1 = collect(p, st, b2, step=step)
        pend = diff_sections_async(t0, t1)

        def resolve() -> Thresholds:
            return Thresholds(eps=eps, margin=margin, per_tensor=pend())

        resolve.ready = pend.ready
        return resolve

    def estimate(p, st, live_batch, step: int = 0) -> Thresholds:
        return submit(p, st, live_batch, step=step)()

    estimate.submit = submit
    return estimate
