"""Equivalence checker + diagnosis report (paper §4.4, §3 steps 4-5).

Compares a candidate trace against the reference trace using the estimated
FP-round-off thresholds, produces a per-tensor report, and localizes the
first diverging module in forward order (activations) / the deepest diverging
module in backward order (gradients).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import numpy as np

from repro.core import canonical as C
from repro.core.collector import Trace
from repro.core.relerr_engine import _to_rel_err, section_sq_norms
from repro.core.thresholds import Thresholds

DEFAULT_KINDS = (C.KIND_ACT, C.KIND_ACT_GRAD, C.KIND_PARAM_GRAD,
                 C.KIND_MAIN_GRAD, C.KIND_PARAM_POST)


@dataclass
class CheckRecord:
    kind: str
    name: str
    rel_err: float
    threshold: float
    flagged: bool
    note: str = ""


@dataclass
class Report:
    records: list[CheckRecord] = field(default_factory=list)
    merge_problems: list[str] = field(default_factory=list)
    missing: list[str] = field(default_factory=list)
    localized: Optional[str] = None       # module blamed for the bug
    localization_mode: str = "propagation"  # or "rewrite"

    @property
    def flagged(self) -> list[CheckRecord]:
        return [r for r in self.records if r.flagged]

    @property
    def passed(self) -> bool:
        return not self.flagged and not self.merge_problems

    @property
    def loud(self) -> list[CheckRecord]:
        """Records failing with non-finite rel-err (NaN/Inf poisoning) —
        a LOUD failure, reported separately from threshold exceedances."""
        return [r for r in self.records if "LOUD" in r.note]

    def first_flagged_activation(self) -> Optional[CheckRecord]:
        for r in self.records:            # records kept in forward tap order
            if r.kind == C.KIND_ACT and r.flagged:
                return r
        return None

    def summary(self, max_rows: int = 12) -> str:
        lines = []
        n_flag = len(self.flagged)
        status = "PASS" if self.passed else "FAIL"
        lines.append(f"TTrace report: {status} "
                     f"({n_flag}/{len(self.records)} tensors flagged, "
                     f"{len(self.merge_problems)} merge problems)")
        if self.loud:
            lines.append(f"  LOUD: {len(self.loud)} tensors with "
                         f"non-finite rel_err (NaN/Inf poisoning)")
        for p in self.merge_problems:
            lines.append(f"  [merge] {p}")
        shown = 0
        for r in self.records:
            if r.flagged and shown < max_rows:
                lines.append(f"  [{r.kind}] {r.name}: rel_err={r.rel_err:.3e} "
                             f"> thr={r.threshold:.3e} {r.note}")
                shown += 1
        if n_flag > shown:
            lines.append(f"  ... {n_flag - shown} more flagged tensors")
        if self.localized:
            lines.append(f"  LOCALIZED ({self.localization_mode}): bug in "
                         f"module '{self.localized}'")
        return "\n".join(lines)


def _module_of(name: str) -> str:
    return name.rsplit("/", 1)[0] if "/" in name else name


def collect_section_pairs(ref: Trace, cand: Trace, kinds=DEFAULT_KINDS):
    """Pass 1 of a differential check — metadata only, NO host transfer.

    Walks the requested sections of both traces and returns
    ``(entries, leaves_ref, leaves_cand, missing)`` where ``entries`` is an
    ordered list of ``(kind, name, note)``: ``note is None`` marks a
    comparable pair (its leaves appear, in order, in the two leaf lists)
    and a non-None note records a shape mismatch (flagged unconditionally).
    Shapes come from the stored leaves without materializing numpy, so this
    pass is free to run on the training hot path; the reduction itself
    (pass 2) can then be dispatched on device and resolved later — the
    contract the async supervisor pipeline builds on.
    """
    entries: list[tuple[str, str, Optional[str]]] = []
    leaves_ref, leaves_cand, missing = [], [], []
    for kind in kinds:
        rs, cs = ref.section(kind), cand.section(kind)
        for name in rs:
            if name not in cs:
                missing.append(f"{kind}:{name} missing from candidate")
                continue
            sa, sb = rs.shape_of(name), cs.shape_of(name)
            if sa != sb:
                entries.append((kind, name, f"shape {sb} != ref {sa}"))
                continue
            entries.append((kind, name, None))
            leaves_ref.append(rs.raw(name))
            leaves_cand.append(cs.raw(name))
    return entries, leaves_ref, leaves_cand, missing


def merge_problems_of(trace) -> list[str]:
    """The per-rank merge problems a candidate trace carries, if any.

    Multi-rank candidates (the 1F1B pipeline) attach their ``MergeReport``
    as ``trace.meta['merge_report']``; coverage violations there are
    check-failing evidence on their own, independent of any value
    divergence."""
    meta = getattr(trace, "meta", None) or {}
    rep = meta.get("merge_report")
    if rep is None or rep.ok:
        return []
    return list(rep.problems())


def report_from_errs(entries, errs, thr: Thresholds, missing=(),
                     thr_scale: float = 1.0, merge_problems=()) -> Report:
    """Pass 2 of a differential check: fold per-pair relative errors back
    into a ``Report`` (records in section order) and localize.

    ``errs`` is an iterable of rel-errs aligned with the comparable
    (note-is-None) entries of ``collect_section_pairs``.  ``thr_scale``
    widens thresholds — a float applies uniformly, a ``{kind: float}``
    mapping per trace kind; the supervisor's per-step drift allowance for
    multi-step runs, 1.0 for the single-step check.  ``merge_problems``
    (per-rank trace merge violations) fail the report unconditionally.
    """
    rep = Report()
    rep.missing.extend(missing)
    rep.merge_problems.extend(merge_problems)
    it = iter(errs)
    for kind, name, mismatch in entries:
        if mismatch is not None:
            rep.records.append(CheckRecord(
                kind, name, float("inf"), 0.0, True, note=mismatch))
            continue
        e = float(next(it))
        scale = (thr_scale.get(kind, 1.0) if isinstance(thr_scale, dict)
                 else thr_scale)
        t = thr.threshold(kind, name) * scale
        if not np.isfinite(e):
            # NaN/Inf is a LOUD failure, not a threshold question — and a
            # NaN rel-err compares False against every threshold, so
            # without this branch a poisoned step would silently PASS
            rep.records.append(CheckRecord(
                kind, name, e, t, True, note="LOUD non-finite rel_err"))
            continue
        rep.records.append(CheckRecord(kind, name, e, t, e > t))
    _localize_propagation(rep)
    return rep


def _localize_propagation(rep: Report) -> None:
    # propagation-order localization: the first flagged forward activation is
    # the earliest module whose computation diverged (paper §3 step 4).
    first = rep.first_flagged_activation()
    if first is not None:
        rep.localized = _module_of(first.name)
        rep.localization_mode = "propagation"
    elif rep.flagged:
        # Backward-only bug: wrong gradients propagate UPSTREAM (toward the
        # embedding), so walking the backward pass from the loss, the first
        # wrong tensor sits at the buggy module — i.e. the LAST flagged
        # activation gradient in forward order.
        agrads = [r for r in rep.records
                  if r.kind == C.KIND_ACT_GRAD and r.flagged]
        pgrads = [r for r in rep.records
                  if r.kind == C.KIND_PARAM_GRAD and r.flagged]
        mgrads = [r for r in rep.records
                  if r.kind == C.KIND_MAIN_GRAD and r.flagged]
        if agrads:
            rep.localized = _module_of(agrads[-1].name)
            rep.localization_mode = "backward"
        elif pgrads:
            # only weight grads wrong (e.g. stale wgrad buffers): blame the
            # module owning the parameter (strip generic leaf names; norm
            # weights ARE their module)
            name = pgrads[-1].name
            head, _, leaf = name.rpartition(".")
            rep.localized = head if leaf in ("w", "b") else name
            rep.localization_mode = "backward"
        elif mgrads:
            # fp32 main grads wrong but raw grads fine: the optimizer-side
            # processing of that parameter's gradient is at fault
            rep.localized = _module_of(mgrads[0].name)
            rep.localization_mode = "optimizer"
        else:
            # ONLY post-step params flagged: forward, backward and the main
            # grads all agree — the parameter update itself is wrong (stale
            # ZeRO gathers, skipped partitions, ...)
            rep.localized = "optimizer"
            rep.localization_mode = "optimizer"
    return None


def compare_traces(ref: Trace, cand: Trace, thr: Thresholds,
                   kinds=DEFAULT_KINDS, thr_scale: float = 1.0) -> Report:
    """Differential check of two traces (paper §3 step 4): one metadata pass,
    then ONE batched device reduction over every comparable pair of every
    requested section, then threshold comparison + localization."""
    entries, la, lb, missing = collect_section_pairs(ref, cand, kinds)
    errs = _to_rel_err(section_sq_norms(la, lb))
    return report_from_errs(entries, errs, thr, missing=missing,
                            thr_scale=thr_scale,
                            merge_problems=merge_problems_of(cand))


def localize_with_rewrites(run_ref, run_cand, batch, ref_trace: Trace,
                           thr: Thresholds, scope_filter=None) -> Report:
    """Rewrite-mode localization (paper §3 step 5): overwrite EVERY module's
    input with a consistent generated tensor in both the reference and the
    candidate, so an error in one module cannot propagate to the next; any
    module whose OUTPUT still diverges is buggy in isolation.

    ``run_ref/run_cand(batch, rewrites) -> Trace``.
    """
    from repro.core.generator import generate
    rewrites = {}
    for name, a in ref_trace.activations.items():
        if not name.endswith("/input"):
            continue
        if scope_filter is not None and not scope_filter(name):
            continue
        cid = C.tap_to_id(name, C.KIND_ACT)
        scale = float(np.std(a)) or 1.0
        rewrites[name] = generate(cid, a.shape, str(a.dtype), scale=scale)
    t_ref = run_ref(batch, rewrites)
    t_cand = run_cand(batch, rewrites)
    rep = compare_traces(t_ref, t_cand, thr, kinds=(C.KIND_ACT,))
    # under rewrites, every flagged *output* names its buggy module directly;
    # report the FIRST one in forward execution order
    order = t_ref.meta.get("fwd_order") or [r.name for r in rep.records]
    rank = {n: i for i, n in enumerate(order)}
    flagged_mods = [(rank.get(r.name, 1 << 30), _module_of(r.name))
                    for r in rep.records
                    if r.flagged and r.name.endswith("/output")]
    if flagged_mods:
        rep.localized = min(flagged_mods)[1]
    else:
        # no module diverges in ISOLATION: the bug lives in the glue
        # between modules (residual stream, stage-boundary communication) —
        # rewrites sever exactly the module-input paths, so module outputs
        # all agree while the corrupted stream resurfaces only at
        # downstream stream taps.  Blaming those would mis-localize;
        # leave the verdict to the propagation report instead.
        rep.localized = None
    rep.localization_mode = "rewrite"
    return rep
