"""TTrace top-level API (paper §3 debugging workflow).

    result = ttrace_check(
        reference=make_model_runner(model, params, batch_opts...),
        candidate=<runner from repro.parallel or any step fn>,
        batch=batch,
        eps=machine epsilon of the recipe,
    )

A *runner* is ``fn(batch, rewrites) -> Trace``.  The harness performs:
  step 1  threshold estimation (reference run + eps-perturbed reference run)
  step 3  candidate run with trace collection
  step 4  differential testing -> Report
  step 5  if flagged: rewrite-mode localization (module-isolated inputs)

Integration cost for a new step function is the runner closure — the
"fewer than 10 lines of code" the paper advertises.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Optional

from repro.core.checker import Report, compare_traces, localize_with_rewrites
from repro.core.collector import Trace, trace_pair_step, trace_train_step
from repro.core.thresholds import MACHINE_EPS, Thresholds, estimate_thresholds


@dataclass
class TTraceResult:
    report: Report                      # step-4 differential report
    localization: Optional[Report]      # step-5 rewrite-mode report (if run)
    thresholds: Thresholds
    reference: Trace
    candidate: Trace

    @property
    def passed(self) -> bool:
        return self.report.passed

    @property
    def localized_module(self) -> Optional[str]:
        if self.localization is not None and self.localization.localized:
            return self.localization.localized
        return self.report.localized

    def summary(self) -> str:
        s = self.report.summary()
        if self.localization is not None:
            s += "\n--- rewrite-mode localization ---\n"
            s += self.localization.summary()
        return s


def make_model_runner(model, params, opt=None, opt_state=None,
                      tap_filter=None, jit=True) -> Callable:
    """Reference runner over the single-device model zoo.

    The returned runner also exposes ``run.pair(batch2) -> (Trace, Trace)``
    — two batches stacked on a leading axis collected in ONE vmapped
    compiled call — which threshold estimation uses to fuse the base and
    eps-perturbed reference runs for float-input models.
    """
    def run(batch, rewrites=None) -> Trace:
        tr, _, _ = trace_train_step(model, params, batch, opt=opt,
                                    opt_state=opt_state, rewrites=rewrites,
                                    tap_filter=tap_filter, jit=jit)
        return tr

    def run_pair(batch2):
        return trace_pair_step(model, params, batch2, opt=opt,
                               opt_state=opt_state, tap_filter=tap_filter,
                               jit=jit)

    run.pair = run_pair
    return run


def make_decode_runner(model, params, decode_fn=None, taps_every: int = 1):
    """Inference-mode runner (paper §7 'extension to inference', implemented
    here): steps the decode path over the prompt, tapping each step's logits
    and the final cache leaves.  ``decode_fn(params, cache, tokens, pos)``
    defaults to ``model.decode_step``; pass an alternative implementation
    (e.g. naive vs absorbed MLA decode) as the candidate."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.core.collector import Trace, flatten_named

    fn = decode_fn or model.decode_step
    fn = jax.jit(fn)

    def run(batch, rewrites=None) -> Trace:
        toks = jnp.asarray(batch["tokens"])
        B, T = toks.shape
        cache = model.init_cache(B, T)
        tr = Trace()
        for t in range(T):
            logits, cache = fn(params, cache, toks[:, t:t + 1], jnp.int32(t))
            if t % taps_every == 0:
                tr.activations[f"decode.t{t}/logits"] = np.asarray(
                    logits, np.float32)
        for name, leaf in flatten_named(cache).items():
            tr.activations[f"decode.final_cache.{name}/value"] =                 np.asarray(leaf, np.float32)
        tr.meta["fwd_order"] = list(tr.activations)
        tr.loss = float(np.mean(tr.activations[f"decode.t{T-1}/logits"]))
        return tr

    return run


def ttrace_supervise(model, cfg, pcfg, opt, params=None, steps: int = 8,
                     batch_fn: Optional[Callable] = None, **kwargs):
    """Multi-step analogue of ``ttrace_check``: run reference and candidate
    training loops in lockstep for ``steps`` steps with online (async)
    checking, and on a flag bisect to the first bad step and localize.

    Recipe-generic: ``pcfg`` selects the shard_map (dense/MoE/ZeRO-1),
    staged pipeline (``pp=N``), real multi-device 1F1B pipeline
    (``pp=N, pp_schedule="1f1b", microbatches=M`` — per-rank traces merged
    before checking) or FP8 (``fp8="tile128"`` etc., checked under BF16
    epsilon automatically) candidate.

    Thin facade over ``repro.supervise.Supervisor`` — ``kwargs`` map onto
    ``SuperviseConfig`` fields (``check_every``, ``async_window``,
    ``ckpt_every``, ``reestimate_every``, ...) plus
    ``batch_size``/``seq_len``/``log_fn`` for the default synthetic batch
    stream.  Returns a ``SuperviseResult`` whose
    ``summary()``/``passed``/``localized_module`` mirror ``TTraceResult``.
    """
    from repro.supervise import Supervisor, SuperviseConfig
    sup_kw = {k: kwargs.pop(k) for k in ("batch_size", "seq_len", "log_fn")
              if k in kwargs}
    scfg = SuperviseConfig(steps=steps, **kwargs)
    return Supervisor(model, cfg, pcfg, opt, params=params, scfg=scfg,
                      batch_fn=batch_fn, **sup_kw).run()


def ttrace_check(reference: Callable, candidate: Callable, batch: dict,
                 eps: float = MACHINE_EPS["float32"], margin: float = 8.0,
                 localize: bool = True, seed: int = 0,
                 estimate: bool = True) -> TTraceResult:
    if not estimate:
        # floor-only thresholds (decode runners have integer inputs and no
        # rewrite surface; margin * floor_mult * eps per tensor)
        thr = Thresholds(eps=eps, margin=margin)
        ref_trace = reference(batch, None)
    else:
        thr, ref_trace = estimate_thresholds(reference, batch, eps, margin,
                                             seed)
    cand_trace = candidate(batch, None)
    report = compare_traces(ref_trace, cand_trace, thr)
    loc = None
    if localize and not report.passed:
        loc = localize_with_rewrites(reference, candidate, batch, ref_trace,
                                     thr)
    return TTraceResult(report=report, localization=loc, thresholds=thr,
                        reference=ref_trace, candidate=cand_trace)
