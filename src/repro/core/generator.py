"""Consistent distributed tensor generator (paper §4.2).

The canonical identifier of a tensor is hashed into a PRNG seed; the same
logical full tensor is generated for the single-device reference and for the
distributed candidate, which receives only its shard (sliced per the user's
ShardSpec).  Numpy's Philox generator is used so values are independent of
device layout, JAX version and backend — determinism is the whole point.

Uses: (1) module-input rewriting for bug localization (§3 step 5), where every
module's input is overwritten so an upstream error cannot propagate; and
(2) injecting consistent main gradients to differentially test the optimizer.
"""
from __future__ import annotations

import numpy as np

from repro.core.annotations import ShardSpec, shard_concat_dim, slices_for_rank
from repro.core.canonical import CanonicalId


def _rng(seed: int) -> np.random.Generator:
    return np.random.Generator(np.random.Philox(seed))


def generate(cid, shape, dtype="float32", dist: str = "normal",
             scale: float = 1.0) -> np.ndarray:
    """Generate the logical full tensor for ``cid`` (CanonicalId or str)."""
    seed = cid.seed() if isinstance(cid, CanonicalId) else \
        CanonicalId(0, 0, "gen", str(cid), "value").seed()
    rng = _rng(seed)
    if dist == "normal":
        x = rng.standard_normal(shape, dtype=np.float32) * scale
    elif dist == "uniform":
        x = (rng.random(shape, dtype=np.float32) * 2 - 1) * scale
    else:
        raise ValueError(dist)
    return x.astype(dtype)


def generate_shard(cid, global_shape, spec: ShardSpec, sizes: dict,
                   coords: dict, dtype="float32", dist="normal",
                   scale: float = 1.0) -> np.ndarray:
    """The rank-local shard of the generated logical full tensor."""
    full = generate(cid, global_shape, dtype, dist, scale)
    return extract_shard(full, spec, sizes, coords)


def extract_shard(full: np.ndarray, spec: ShardSpec, sizes: dict,
                  coords: dict) -> np.ndarray:
    frags = slices_for_rank(spec, full.shape, sizes, coords)
    pieces = [full[f] for f in frags]
    if len(pieces) == 1:
        return pieces[0]
    cdim = shard_concat_dim(spec)
    assert cdim is not None, "multi-fragment shard without a concat dim"
    return np.concatenate(pieces, axis=cdim % full.ndim)


def perturb(x: np.ndarray, rel_eps: float, seed: int = 0) -> np.ndarray:
    """x + dX with ||dX|| = rel_eps * ||x|| (threshold estimation, §5.2)."""
    rng = _rng(seed ^ 0x9E3779B97F4A7C15)
    d = rng.standard_normal(x.shape).astype(np.float32)
    nx = np.linalg.norm(x.astype(np.float32))
    nd = np.linalg.norm(d)
    if nd == 0 or nx == 0:
        return x
    return (x.astype(np.float32) + d * (rel_eps * nx / nd)).astype(x.dtype)
