"""Functional trace taps — the JAX-native analogue of PyTorch module hooks.

TTrace (paper §4.3) collects per-module forward inputs/outputs and backward
gradients with PyTorch module/tensor hooks.  JAX is functional, so instead:

* every framework module calls ``ctx.tap(role, x)`` inside the traced step;
* in **collect** mode the tapped values become auxiliary outputs of the jitted
  function (pure — works under jit, pjit, remat and scan);
* activation *gradients* are obtained with **zero probes**: ``tap`` adds a
  zeros-valued probe parameter to the activation, and ``jax.grad`` w.r.t. the
  probe pytree equals the gradient w.r.t. the tapped activation;
* in **rewrite** mode (paper §3 step 5, bug localization) the tap substitutes
  a consistent generated tensor for the module input, so an error in one
  module cannot propagate into the next.

Tap names are canonical module paths (see core/canonical.py) joined with the
tensor role, e.g. ``layers.3.attn.linear_qkv/output``.
"""
from __future__ import annotations

from contextlib import contextmanager
from typing import Optional

import jax
import jax.numpy as jnp

# tensor roles (paper §4.3 trace kinds)
ROLE_INPUT = "input"
ROLE_OUTPUT = "output"


class TraceContext:
    """Threaded through a model's forward; records / rewrites tapped tensors.

    modes:
      "off"      — taps are identity (production path; also the dry-run path)
      "collect"  — record forward values; add zero probes for grad collection
      "rewrite"  — overwrite tapped tensors with ``rewrites[name]`` AND record
    """

    def __init__(self, mode: str = "collect", probes: Optional[dict] = None,
                 rewrites: Optional[dict] = None):
        assert mode in ("off", "collect", "rewrite")
        self.mode = mode
        self.probes = probes
        self.rewrites = rewrites or {}
        self.fwd: dict[str, jax.Array] = {}
        self.meta: dict[str, dict] = {}
        self._prefix: list[str] = []

    # ---- scoping -----------------------------------------------------------
    @contextmanager
    def scope(self, name: str):
        self._prefix.append(name)
        try:
            yield self
        finally:
            self._prefix.pop()

    def path(self, role: str = "") -> str:
        p = ".".join(self._prefix)
        if not role:
            return p
        return f"{p}/{role}" if p else role

    # ---- tapping -----------------------------------------------------------
    def tap(self, role: str, x: jax.Array, **meta) -> jax.Array:
        if self.mode == "off":
            return x
        name = self.path(role)
        if self.mode == "rewrite" and name in self.rewrites:
            # straight-through overwrite: the VALUE becomes the rewrite, but
            # gradients still flow through the original tensor — so threshold
            # estimation (eps-perturbed rewrites) keeps the true gradient
            # topology, and localization mode stays differentiable.  A
            # callable rewrite maps the tapped value to its replacement
            # inside the trace (the fused pair estimator perturbs the
            # embedding output per vmapped row this way).
            rw = self.rewrites[name]
            r = (rw(x) if callable(rw) else rw).astype(x.dtype)
            x = x + jax.lax.stop_gradient(r - x)
        if name in self.fwd:
            raise ValueError(
                f"duplicate canonical tensor identifier {name!r} in one trace")
        self.fwd[name] = x
        self.meta[name] = dict(meta)
        if self.probes is not None and name in self.probes:
            x = x + self.probes[name].astype(x.dtype)
        return x

    def tap_scan(self, role: str, x: jax.Array, **meta) -> jax.Array:
        """Tap inside a lax.scan body: values are recorded stacked along the
        scan (layer) axis; the collector splits them into per-layer canonical
        names afterwards.  Probes/rewrites are not supported inside scans —
        scanned stacks are for dry-run-scale models where ctx is "off"."""
        if self.mode == "off":
            return x
        return self.tap(role, x, scanned=True, **meta)


class _NullCtx(TraceContext):
    def __init__(self):
        super().__init__(mode="off")

    def tap(self, role, x, **meta):
        return x


NULL_CTX = _NullCtx()


def ensure_ctx(ctx: Optional[TraceContext]) -> TraceContext:
    return NULL_CTX if ctx is None else ctx


def zero_probes_like(shapes: dict[str, jax.ShapeDtypeStruct],
                     select=None) -> dict[str, jax.Array]:
    """Build the zero-probe pytree for the tap names in ``shapes``.

    ``select`` optionally restricts which taps receive probes (activation
    gradients are only defined for tensors on the differentiation path)."""
    out = {}
    for name, sd in shapes.items():
        if select is not None and not select(name):
            continue
        out[name] = jnp.zeros(sd.shape, jnp.float32)
    return out
