"""Canonical tensor identifiers and pipeline layer-index mapping (paper §4.1).

A tensor is uniquely identified inside a trace by

    CanonicalId(iteration, microbatch, kind, module, role)

where ``module`` is the *canonical* module name: local layer indices assigned
by pipeline parallelism (PP) and virtual/interleaved pipeline parallelism
(VPP) are mapped back to the reference model's global layer indices (paper
Fig 5) before naming.
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass

# trace kinds (paper §4.3)
KIND_ACT = "activation"
KIND_ACT_GRAD = "act_grad"
KIND_PARAM = "param"
KIND_PARAM_GRAD = "param_grad"
KIND_MAIN_GRAD = "main_grad"
KIND_PARAM_POST = "param_post_step"
KINDS = (KIND_ACT, KIND_ACT_GRAD, KIND_PARAM, KIND_PARAM_GRAD,
         KIND_MAIN_GRAD, KIND_PARAM_POST)


@dataclass(frozen=True, order=True)
class CanonicalId:
    iteration: int
    microbatch: int
    kind: str
    module: str     # canonical module path, e.g. "layers.12.self_attention.linear_qkv"
    role: str       # "input" | "output" | param leaf name | ...

    def __str__(self):
        return (f"it{self.iteration}/mb{self.microbatch}/{self.kind}/"
                f"{self.module}/{self.role}")

    def seed(self) -> int:
        """Stable 63-bit seed for the consistent tensor generator (§4.2)."""
        h = hashlib.blake2b(str(self).encode(), digest_size=8).digest()
        return int.from_bytes(h, "little") & 0x7FFF_FFFF_FFFF_FFFF


def tap_to_id(tap_name: str, kind: str, iteration: int = 0,
              microbatch: int = 0) -> CanonicalId:
    """Split a tap path ``module.path/role`` into a CanonicalId."""
    if "/" in tap_name:
        module, role = tap_name.rsplit("/", 1)
    else:
        module, role = tap_name, "value"
    return CanonicalId(iteration, microbatch, kind, module, role)


# ---------------------------------------------------------------------------
# PP / VPP layer-index mapping (paper Fig 5)
# ---------------------------------------------------------------------------
#
# Megatron interleaved schedule: the model's L layers are cut into
# pp_size * vpp_size contiguous chunks of ``L / (pp*vpp)`` layers.  Chunk
# (vpp_rank, pp_rank) holds global layers starting at
#     vpp_rank * pp_size * cpl  +  pp_rank * cpl
# Each stage numbers its local layers 0..(L/pp - 1) across its vpp chunks.


def chunk_layers(n_layers: int, pp_size: int, vpp_size: int) -> int:
    if n_layers % (pp_size * vpp_size) != 0:
        raise ValueError(
            f"{n_layers} layers not divisible by pp{pp_size} x vpp{vpp_size}")
    return n_layers // (pp_size * vpp_size)


def canonical_layer_index(local_idx: int, pp_rank: int, pp_size: int,
                          vpp_rank: int, vpp_size: int, n_layers: int) -> int:
    """Map a stage-local layer index to the reference (global) layer index.

    ``local_idx`` counts layers *within the (pp_rank, vpp_rank) chunk* —
    Megatron gives each virtual chunk its own offset-free numbering, which is
    exactly the ambiguity the canonical name resolves (paper Fig 5).
    """
    if not (0 <= pp_rank < pp_size and 0 <= vpp_rank < vpp_size):
        raise ValueError("rank out of range")
    cpl = chunk_layers(n_layers, pp_size, vpp_size)
    if not (0 <= local_idx < cpl):
        raise ValueError(f"local layer {local_idx} outside chunk of {cpl}")
    return vpp_rank * pp_size * cpl + pp_rank * cpl + local_idx


def local_layer_index(global_idx: int, pp_size: int, vpp_size: int,
                      n_layers: int) -> tuple[int, int, int]:
    """Inverse of ``canonical_layer_index``: -> (pp_rank, vpp_rank, local_idx)."""
    cpl = chunk_layers(n_layers, pp_size, vpp_size)
    chunk = global_idx // cpl
    vpp_rank, pp_rank = divmod(chunk, pp_size)
    return pp_rank, vpp_rank, global_idx % cpl


def canonicalize_module(module: str, pp_rank: int, pp_size: int,
                        vpp_rank: int = 0, vpp_size: int = 1,
                        n_layers: int | None = None,
                        layer_key: str = "layers.") -> str:
    """Rewrite ``layers.<local>`` inside a module path to the global index."""
    if layer_key not in module or pp_size * vpp_size == 1:
        return module
    pre, rest = module.split(layer_key, 1)
    num, dot, tail = rest.partition(".")
    gidx = canonical_layer_index(int(num), pp_rank, pp_size, vpp_rank,
                                 vpp_size, n_layers)
    return f"{pre}{layer_key}{gidx}{dot}{tail}"
