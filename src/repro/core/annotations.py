"""User-written sharding annotations (paper §3 step 2, Fig 2).

The user declares, per parameter and per traced activation, which tensor
dimension each parallel axis shards — e.g.::

    annotations = Annotations.from_dict({
        "params": {
            "embedding.word_embeddings":                {"tp_dim": 0},
            "layers.*.self_attention.linear_qkv.w":     {"tp_dim": 1},
            "layers.*.self_attention.linear_proj.w":    {"tp_dim": 0},
            "layers.*.mlp.gate.w":                      {"tp_dim": 1},
        },
        "acts": {
            "layers.*.self_attention/input":  {"sp_dim": 1, "cp_dim": 1},
            "layers.*.self_attention/output": {"cp_dim": 1},
            "layers.*.mlp/core":              {"tp_dim": -1},
        },
    })

TTrace infers the shard mapping (slices of the logical full tensor owned by
each rank) from these specs + the mesh coordinates — the user never writes
slice arithmetic (paper §4.1).
"""
from __future__ import annotations

import fnmatch
from dataclasses import dataclass, field
from typing import Optional

# parallel axes in the order nested splits are applied (outer -> inner).
# cp splits the sequence before sp does: the physical layout is
# cp-major / sp-minor, matching PartitionSpec(("cp", "tp")) on the seq dim.
AXES = ("dp", "ep", "cp", "tp", "sp")


@dataclass(frozen=True)
class ShardSpec:
    tp_dim: Optional[int] = None
    sp_dim: Optional[int] = None
    cp_dim: Optional[int] = None
    dp_dim: Optional[int] = None
    ep_dim: Optional[int] = None
    cp_mode: str = "contiguous"    # "contiguous" | "zigzag" (striped, Fig 6)

    def dim_for(self, axis: str) -> Optional[int]:
        return getattr(self, f"{axis}_dim")

    @property
    def replicated_axes(self) -> tuple[str, ...]:
        return tuple(a for a in AXES if self.dim_for(a) is None)


REPLICATED = ShardSpec()


def _split_range(lo: int, hi: int, n: int, r: int) -> tuple[int, int]:
    size = hi - lo
    if size % n != 0:
        raise ValueError(f"extent {size} not divisible by {n} shards")
    c = size // n
    return lo + r * c, lo + (r + 1) * c


def slices_for_rank(spec: ShardSpec, global_shape: tuple[int, ...],
                    sizes: dict[str, int], coords: dict[str, int]
                    ) -> list[tuple[slice, ...]]:
    """The (possibly non-contiguous) slices of the logical full tensor owned
    by the rank at ``coords``.  Zigzag context parallelism gives each rank two
    stripes (rank r of R owns chunks r and 2R-1-r), hence a *list* of slices.
    """
    ndim = len(global_shape)
    frags: list[list[tuple[int, int]]] = [[(0, s) for s in global_shape]]
    for axis in AXES:
        n = sizes.get(axis, 1)
        dim = spec.dim_for(axis)
        if n == 1 or dim is None:
            continue
        dim = dim % ndim
        r = coords.get(axis, 0)
        new_frags = []
        for fr in frags:
            lo, hi = fr[dim]
            if axis == "cp" and spec.cp_mode == "zigzag":
                for chunk in (r, 2 * n - 1 - r):
                    clo, chi = _split_range(lo, hi, 2 * n, chunk)
                    nf = list(fr)
                    nf[dim] = (clo, chi)
                    new_frags.append(nf)
            else:
                nlo, nhi = _split_range(lo, hi, n, r)
                nf = list(fr)
                nf[dim] = (nlo, nhi)
                new_frags.append(nf)
        frags = new_frags
    return [tuple(slice(lo, hi) for lo, hi in fr) for fr in frags]


def shard_concat_dim(spec: ShardSpec) -> Optional[int]:
    """The dim along which a multi-fragment shard (zigzag cp) concatenates."""
    return spec.cp_dim if spec.cp_mode == "zigzag" else None


@dataclass
class Annotations:
    params: dict[str, ShardSpec] = field(default_factory=dict)
    acts: dict[str, ShardSpec] = field(default_factory=dict)

    @classmethod
    def from_dict(cls, d: dict) -> "Annotations":
        def conv(section):
            out = {}
            for pat, spec in section.items():
                out[pat] = spec if isinstance(spec, ShardSpec) else ShardSpec(**spec)
            return out
        return cls(params=conv(d.get("params", {})),
                   acts=conv(d.get("acts", {})))

    def _lookup(self, table: dict[str, ShardSpec], name: str) -> ShardSpec:
        if name in table:
            return table[name]
        best = None
        for pat, spec in table.items():
            if fnmatch.fnmatchcase(name, pat):
                if best is None or len(pat) > len(best[0]):
                    best = (pat, spec)
        return best[1] if best else REPLICATED

    def param_spec(self, name: str) -> ShardSpec:
        return self._lookup(self.params, name)

    def act_spec(self, name: str) -> ShardSpec:
        return self._lookup(self.acts, name)

    def spec_for(self, kind: str, name: str) -> ShardSpec:
        from repro.core import canonical as C
        if kind in (C.KIND_ACT, C.KIND_ACT_GRAD):
            return self.act_spec(name)
        return self.param_spec(name)
