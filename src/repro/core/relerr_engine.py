"""Batched, device-resident rel-err engine — the checker's comparison core.

``compare_traces`` and ``thresholds._diff_sections`` both reduce to the same
question: for N tensor pairs of one trace section, what are the N relative
Frobenius errors?  This module answers it in (at most) one device dispatch
per section instead of N host-side float64 loops:

* **TPU**: the pairs are packed into two block-aligned flat buffers on
  device and handed to the packed segmented Pallas kernel
  (``repro.kernels.relerr.packed_sq_norms``) — one grid launch, N x 2
  scalars transferred back.
* **CPU**: device buffers ARE host memory, so the fastest executor is f32
  BLAS over zero-copy numpy views — in-place subtract into a reused scratch
  plus two sdot reductions per pair, no float64 temporaries, no
  allocations.  (Packing through host memory or XLA:CPU's reduce codegen
  both lose to this by 3-10x at trace scale.)
* **other accelerators (no Mosaic)**: the same fused
  subtract-square-reduce per pair inside ONE jitted call — a single
  dispatch, leaves stay on device, no difference tensor materialized.
* **below a per-backend size cutoff**: a plain per-pair float64 numpy loop
  — for tiny sections the compile + dispatch overhead of any batched path
  dwarfs the arithmetic, and float64 is the reference semantic.

The selection is automatic from ``jax.default_backend()`` (this replaced
the old ``REPRO_FUSED_RELERR_MIN_ELEMS`` env var); ``mode=`` forces a
specific path for tests and benchmarks.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import relerr as K

# Below this many total section elements the float64 numpy loop wins
# (TPU/GPU: keep even small sections on device — each host transfer costs
# more than a tiny kernel; the CPU crossover uses the per-pair mean below).
MIN_BATCHED_ELEMS = {"tpu": 1 << 12, "gpu": 1 << 14}

# CPU crossover refinement: both executors are per-pair host loops, so the
# crossover tracks the MEAN elements per pair, not the section total — the
# loop pays float64 temporaries per element (2x bandwidth) but less per-pair
# fixed cost than the BLAS scratch path.  Measured on the container's 2-core
# host (see checker_bench's auto rows): loop wins below ~4k elements/pair at
# every section width from 20 to 200 tensors, BLAS above.  The old
# total-elements cutoff misclassified exactly the bench's 50x128k section
# (721us batched vs 535us loop).
MIN_BATCHED_MEAN_ELEMS_CPU = 1 << 12


def _raw(section, name):
    """Stored leaf without forcing a host copy (Section.raw or dict item)."""
    getter = getattr(section, "raw", None)
    return getter(name) if getter is not None else section[name]


def rel_err_np(a, b) -> float:
    """Per-pair float64 reference: ||a-b|| / ||a|| (paper §2.2)."""
    a64 = np.asarray(a, np.float64)
    b64 = np.asarray(b, np.float64)
    na = np.linalg.norm(a64)
    d = np.linalg.norm(a64 - b64)
    return float(d / na) if na > 0 else float(d)


# ---------------------------------------------------------------------------
# device paths
# ---------------------------------------------------------------------------

@jax.jit
def _fused_pair_sq_norms(leaves_a, leaves_b):
    """One compiled call over all pairs: [(||a-b||^2, ||a||^2)] -> (N, 2).

    Retraces per section signature (pytree of shapes/dtypes); the jit cache
    makes repeated checks of same-shaped traces free.
    """
    dd, aa = [], []
    for a, b in zip(leaves_a, leaves_b):
        a = a.reshape(-1).astype(jnp.float32)
        b = b.reshape(-1).astype(jnp.float32)
        d = a - b
        dd.append(jnp.vdot(d, d))
        aa.append(jnp.vdot(a, a))
    return jnp.stack([jnp.stack(dd), jnp.stack(aa)], axis=1)


def pack_device(leaves_a, leaves_b, block: int = K.DEFAULT_BLOCK):
    """Pack pairs into the kernel's flat block-aligned layout on device.

    Returns (a_flat, b_flat, seg_ids, counts); see kernels.relerr for the
    layout contract.  Metadata is computed host-side from static shapes —
    no leaf is transferred.
    """
    sizes = [int(np.prod(x.shape)) for x in leaves_a]
    nblocks = [max(1, -(-s // block)) for s in sizes]

    def pad(x):
        f = jnp.ravel(x).astype(jnp.float32)
        p = -f.shape[0] % block if f.shape[0] else block
        return jnp.pad(f, (0, p)) if p else f

    a_flat = jnp.concatenate([pad(x) for x in leaves_a])
    b_flat = jnp.concatenate([pad(x) for x in leaves_b])
    seg_ids = np.repeat(np.arange(len(sizes), dtype=np.int32), nblocks)
    counts = np.concatenate([
        np.clip(s - np.arange(nb, dtype=np.int64) * block, 0, block)
        for s, nb in zip(sizes, nblocks)]).astype(np.int32)
    return a_flat, b_flat, jnp.asarray(seg_ids), jnp.asarray(counts)


def _packed_path(leaves_a, leaves_b) -> np.ndarray:
    from repro.kernels import ops     # honors the REPRO_PALLAS_INTERPRET
    a_flat, b_flat, seg_ids, counts = pack_device(
        [jnp.asarray(x) for x in leaves_a], [jnp.asarray(x) for x in leaves_b])
    out = ops.packed_sq_norms(a_flat, b_flat, seg_ids, counts,
                              n_segments=len(leaves_a))
    return np.asarray(out, np.float64)


def _fused_path(leaves_a, leaves_b) -> np.ndarray:
    out = _fused_pair_sq_norms([jnp.asarray(x) for x in leaves_a],
                               [jnp.asarray(x) for x in leaves_b])
    return np.asarray(out, np.float64)


def _blas_path(leaves_a, leaves_b) -> np.ndarray:
    """CPU fast path: f32 BLAS over zero-copy views of the leaves."""
    def as_f32(x):
        v = np.asarray(x)                 # zero-copy for CPU jax f32 arrays
        if v.dtype != np.float32:
            v = np.asarray(v, np.float32)
        return v.reshape(-1)

    out = np.empty((len(leaves_a), 2), np.float64)
    scratch = np.empty(max(int(np.prod(x.shape)) for x in leaves_a),
                       np.float32)
    for i, (a, b) in enumerate(zip(leaves_a, leaves_b)):
        an, bn = as_f32(a), as_f32(b)
        d = scratch[:an.size]
        np.subtract(an, bn, out=d)
        out[i, 0] = np.dot(d, d)
        out[i, 1] = np.dot(an, an)
    return out


# ---------------------------------------------------------------------------
# engine entry points
# ---------------------------------------------------------------------------

def section_sq_norms(leaves_a, leaves_b, mode: str | None = None
                     ) -> np.ndarray:
    """(N, 2) float64 of ``(||a-b||^2, ||a||^2)`` per pair.

    ``mode``: None (auto by backend/size), "loop", "blas", "fused", or
    "packed".
    """
    if not leaves_a:
        return np.zeros((0, 2), np.float64)
    if mode is None:
        backend = jax.default_backend()
        # .size, not np.prod(shape): the selection runs per check and a
        # np.prod call per leaf costs more than the small-section reduction
        total = sum(int(x.size) for x in leaves_a)
        if backend == "cpu":
            # host executors: the crossover is per-pair, not per-section
            mode = ("loop" if total // len(leaves_a)
                    < MIN_BATCHED_MEAN_ELEMS_CPU else "blas")
        elif total < MIN_BATCHED_ELEMS.get(backend, 1 << 19):
            mode = "loop"
        elif backend == "tpu":
            mode = "packed"
        else:
            mode = "fused"
    if mode == "loop":
        out = np.empty((len(leaves_a), 2), np.float64)
        for i, (a, b) in enumerate(zip(leaves_a, leaves_b)):
            a64 = np.asarray(a, np.float64).reshape(-1)
            b64 = np.asarray(b, np.float64).reshape(-1)
            d = a64 - b64
            out[i, 0] = np.dot(d, d)
            out[i, 1] = np.dot(a64, a64)
        return out
    if mode == "blas":
        return _blas_path(leaves_a, leaves_b)
    if mode == "fused":
        return _fused_path(leaves_a, leaves_b)
    if mode == "packed":
        return _packed_path(leaves_a, leaves_b)
    raise ValueError(f"unknown rel-err engine mode {mode!r}")


def sq_norms_async(leaves_a, leaves_b):
    """Dispatch the per-pair ``(||a-b||^2, ||a||^2)`` reduction and return
    the DEVICE ``(N, 2)`` array **without synchronizing**.

    This is the async-checking entry point: the caller keeps the returned
    ``jax.Array`` as a future (JAX dispatch is asynchronous on every
    backend) and materializes it later with ``np.asarray`` — training steps
    dispatched in between overlap with the reduction.  On TPU the packed
    segmented Pallas kernel runs; elsewhere the fused one-dispatch XLA
    reduction.  (The CPU BLAS executor is intentionally NOT used here: it
    computes on the caller's thread, which is exactly the synchronization
    async checking exists to avoid.)
    """
    if not leaves_a:
        return jnp.zeros((0, 2), jnp.float32)
    if jax.default_backend() == "tpu":
        from repro.kernels import ops
        a_flat, b_flat, seg_ids, counts = pack_device(
            [jnp.asarray(x) for x in leaves_a],
            [jnp.asarray(x) for x in leaves_b])
        return ops.packed_sq_norms(a_flat, b_flat, seg_ids, counts,
                                   n_segments=len(leaves_a))
    return _fused_pair_sq_norms([jnp.asarray(x) for x in leaves_a],
                                [jnp.asarray(x) for x in leaves_b])


def _to_rel_err(sq: np.ndarray) -> np.ndarray:
    d = np.sqrt(sq[:, 0])
    na = np.sqrt(sq[:, 1])
    return np.where(na > 0, d / np.maximum(na, 1e-300), d)


def batched_rel_err(section_a, section_b, names=None,
                    mode: str | None = None) -> dict[str, float]:
    """Relative Frobenius errors for every pair in a trace section.

    ``section_a/b``: collector.Section or plain dict; leaves stay device
    resident on the batched paths — only N x 2 scalars reach the host.
    ``names`` defaults to the keys of ``section_a`` present in ``section_b``
    (in ``section_a`` order); pairs must be same-shaped.
    """
    if names is None:
        names = [k for k in section_a if k in section_b]
    leaves_a = [_raw(section_a, n) for n in names]
    leaves_b = [_raw(section_b, n) for n in names]
    errs = _to_rel_err(section_sq_norms(leaves_a, leaves_b, mode=mode))
    return {n: float(e) for n, e in zip(names, errs)}
