"""Trace collector (paper §4.3): runs one training iteration and records

* forward activations of every tapped module (inputs + outputs),
* activation gradients (via zero probes — the functional tensor-hook),
* parameter gradients,
* main (fp32, post-clip) gradients from the optimizer,
* post-step parameters,

as a ``Trace`` whose sections are **lazily device-resident**: leaves stay
``jax.Array`` until something explicitly asks for numpy (``section[name]``
or ``.host()``).  The batched checker (core.relerr_engine) reads the raw
leaves, so a full equivalence check never transfers activations that pass —
only N x 2 reduction scalars cross the device boundary.
"""
from __future__ import annotations

from collections.abc import MutableMapping
from contextlib import nullcontext
from dataclasses import dataclass, field
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.tap import TraceContext


def device_ctx(device):
    """``jax.default_device`` context for ``device`` (no-op when None).

    Computations dispatched inside stay UNCOMMITTED on ``device`` — they run
    there, yet downstream consumers (the differential check's reduction over
    reference AND candidate leaves) remain free to place the consuming
    computation wherever its other operands are committed.  This is how the
    supervisor partitions the reference step onto its own device set without
    ever producing a mixed-committed-device dispatch error.
    """
    return jax.default_device(device) if device is not None else nullcontext()


# ---------------------------------------------------------------------------
# pytree <-> flat named dict
# ---------------------------------------------------------------------------

def _key_str(k) -> str:
    if hasattr(k, "key"):
        return str(k.key)
    if hasattr(k, "idx"):
        return str(k.idx)
    return str(k)


def flatten_named(tree, sep=".") -> dict[str, jax.Array]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    return {sep.join(_key_str(k) for k in path): leaf for path, leaf in flat}


def unflatten_named(names: dict, template):
    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in flat:
        leaves.append(names[".".join(_key_str(k) for k in path)])
    return jax.tree_util.tree_unflatten(treedef, leaves)


# ---------------------------------------------------------------------------
# Trace
# ---------------------------------------------------------------------------

class Section(MutableMapping):
    """One trace kind: an ordered name -> tensor mapping with a lazy host
    boundary.

    Leaves are stored as handed in (``jax.Array`` or numpy).  ``sec[name]``
    / ``.items()`` materialize numpy (cached); ``.raw(name)`` /
    ``.raw_items()`` return the stored leaf without any transfer — the
    contract the batched checker relies on.
    """
    __slots__ = ("_data", "_host")

    def __init__(self, data=None):
        if isinstance(data, Section):
            self._data = dict(data._data)
            self._host = dict(data._host)
        else:
            self._data = dict(data) if data else {}
            self._host = {}

    # ---- lazy host access --------------------------------------------------
    def __getitem__(self, name) -> np.ndarray:
        h = self._host.get(name)
        if h is None:
            h = self._host[name] = np.asarray(self._data[name])
        return h

    def __setitem__(self, name, value):
        self._data[name] = value
        self._host.pop(name, None)

    def __delitem__(self, name):
        del self._data[name]
        self._host.pop(name, None)

    def __iter__(self):
        return iter(self._data)

    def __len__(self):
        return len(self._data)

    def __contains__(self, name):
        return name in self._data

    def __repr__(self):
        return f"Section({list(self._data)!r})"

    # ---- device access -----------------------------------------------------
    def raw(self, name):
        """The stored leaf — no host transfer."""
        return self._data[name]

    def raw_items(self):
        return self._data.items()

    def shape_of(self, name) -> tuple:
        return tuple(self._data[name].shape)

    def host(self) -> dict[str, np.ndarray]:
        """Materialize every leaf to numpy (one explicit bulk transfer)."""
        return {name: self[name] for name in self._data}

    # ---- per-microbatch sections -------------------------------------------
    @classmethod
    def concat(cls, sections, axis: int = 0) -> "Section":
        """Concatenate same-named sections along ``axis`` — the microbatch
        axis of per-rank pipeline traces — without any host transfer
        (leaves stay device-resident; the merger's per-rank path builds
        the reference-shaped sections this way)."""
        secs = [s if isinstance(s, Section) else cls(s) for s in sections]
        if not secs:
            return cls()
        names = list(secs[0])
        for s in secs[1:]:
            if list(s) != names:
                raise ValueError(
                    "per-microbatch sections disagree on tensor names")
        out = cls()
        for n in names:
            out[n] = jnp.concatenate([s.raw(n) for s in secs], axis=axis)
        return out


_SECTION_FIELDS = ("activations", "act_grads", "param_grads", "main_grads",
                   "params_post")


@dataclass
class Trace:
    activations: Section = field(default_factory=Section)
    act_grads: Section = field(default_factory=Section)
    param_grads: Section = field(default_factory=Section)
    main_grads: Section = field(default_factory=Section)
    params_post: Section = field(default_factory=Section)
    loss: float = float("nan")
    grad_norm: float = float("nan")
    meta: dict = field(default_factory=dict)

    def __setattr__(self, name, value):
        # plain dicts (tests, ad-hoc traces) are adopted into lazy Sections
        if name in _SECTION_FIELDS and not isinstance(value, Section):
            value = Section(value)
        object.__setattr__(self, name, value)

    def section(self, kind: str) -> Section:
        from repro.core import canonical as C
        return {C.KIND_ACT: self.activations, C.KIND_ACT_GRAD: self.act_grads,
                C.KIND_PARAM_GRAD: self.param_grads,
                C.KIND_MAIN_GRAD: self.main_grads,
                C.KIND_PARAM_POST: self.params_post}[kind]

    def host(self) -> "Trace":
        """Force every section to host numpy (explicit bulk transfer)."""
        for f in _SECTION_FIELDS:
            getattr(self, f).host()
        return self


# ---------------------------------------------------------------------------
# Reference collector (single-device)
# ---------------------------------------------------------------------------

def tap_shapes(loss_callable, params, batch, rewrites=None
               ) -> tuple[dict, list[str]]:
    """Pass 1: eval_shape the forward to enumerate tap names/shapes.

    Also returns the tap names in FORWARD ORDER (jax sorts dict pytrees, but
    propagation-order bug localization needs execution order)."""
    order: list[str] = []

    def f(params):
        ctx = TraceContext("rewrite" if rewrites else "collect",
                           rewrites=rewrites or {})
        loss_callable(params, batch, ctx)
        order.clear()
        order.extend(ctx.fwd.keys())
        return ctx.fwd

    return jax.eval_shape(f, params), order


def trace_train_step(model, params, batch, opt=None, opt_state=None,
                     rewrites: Optional[dict] = None,
                     collect_act_grads: bool = True,
                     tap_filter: Optional[Callable[[str], bool]] = None,
                     jit: bool = True) -> tuple[Trace, dict, Optional[dict]]:
    """Run ONE training iteration of the single-device reference with full
    trace collection.  Returns (trace, new_params, new_opt_state).

    ``rewrites``: {tap_name: np/jnp array} — overwrite module inputs
    (localization mode / threshold estimation).
    """
    def loss_call(p, b, ctx):
        loss, _ = model.loss(p, b, ctx=ctx)
        return loss

    return trace_fn_step(loss_call, params, batch, opt=opt,
                         opt_state=opt_state, rewrites=rewrites,
                         collect_act_grads=collect_act_grads,
                         tap_filter=tap_filter, jit=jit)


def _make_probes(shapes, tap_filter, collect_act_grads):
    if not collect_act_grads:
        return {}
    return {k: jnp.zeros(s.shape, jnp.float32)
            for k, s in shapes.items()
            if (tap_filter is None or tap_filter(k))
            and jnp.issubdtype(s.dtype, jnp.floating)}


def trace_fn_step(loss_call, params, batch, opt=None, opt_state=None,
                  rewrites=None, collect_act_grads=True, tap_filter=None,
                  jit=True) -> tuple[Trace, dict, Optional[dict]]:
    """Generic collector over any ``loss_call(params, batch, ctx) -> loss``.

    Used for both the reference model and candidate step functions that
    compute loss differently (e.g. pipeline-partitioned execution).
    """
    rewrites_j = (None if rewrites is None
                  else {k: jnp.asarray(v) for k, v in rewrites.items()})
    shapes, fwd_order = tap_shapes(loss_call, params, batch, rewrites_j)
    mode = "rewrite" if rewrites_j else "collect"
    probes = _make_probes(shapes, tap_filter, collect_act_grads)

    def loss_fn(p, probes):
        ctx = TraceContext(mode, probes=probes, rewrites=rewrites_j or {})
        loss = loss_call(p, batch, ctx)
        return loss, ctx.fwd

    def step(p, probes):
        (loss, fwd), (pgrads, agrads) = jax.value_and_grad(
            loss_fn, argnums=(0, 1), has_aux=True)(p, probes)
        return loss, fwd, pgrads, agrads

    step_c = jax.jit(step) if jit else step
    loss, fwd, pgrads, agrads = step_c(params, probes)

    tr = Trace()
    tr.loss = float(loss)
    tr.activations = {k: fwd[k] for k in fwd_order}
    tr.act_grads = {k: agrads[k] for k in fwd_order if k in agrads}
    tr.param_grads = flatten_named(pgrads)
    tr.meta["fwd_order"] = list(fwd_order)

    new_params, new_state = params, opt_state
    if opt is not None:
        upd = jax.jit(opt.update) if jit else opt.update
        new_params, new_state, info = upd(params, pgrads, opt_state)
        tr.main_grads = flatten_named(info.main_grads)
        tr.params_post = flatten_named(new_params)
        tr.grad_norm = float(info.grad_norm)
    return tr, new_params, new_state


# ---------------------------------------------------------------------------
# Once-compiled stateful trace step (the supervisor's lockstep contract)
# ---------------------------------------------------------------------------

def make_trace_step(loss_call, opt, params, batch,
                    collect_act_grads: bool = True, tap_filter=None,
                    jit: bool = True, device=None):
    """Build a trace-collecting FULL train step compiled exactly once.

    ``trace_train_step`` re-traces every call (fresh closures -> fresh jit
    cache entries); a multi-step supervised run cannot afford that.  This
    builder runs tap discovery once against the template ``(params, batch)``
    shapes and returns ``step(params, opt_state, batch) -> (Trace,
    new_params, new_opt_state)`` backed by a single jitted callable —
    every subsequent same-shaped call is a cache hit.

    The returned Trace's sections are lazily device-resident (collector
    contract) and ``trace.loss`` / ``trace.grad_norm`` are left as device
    scalars so the caller's pipeline is never forced to synchronize.

    ``device`` places the step (and its probe constants) on a specific
    device as an UNCOMMITTED default — the supervisor's disjoint
    reference-device set, so reference and candidate steps dispatched
    back-to-back run concurrently.
    """
    shapes, fwd_order = tap_shapes(loss_call, params, batch, None)
    with device_ctx(device):
        probes = _make_probes(shapes, tap_filter, collect_act_grads)

    def _step(p, st, b, pr):
        def loss_fn(pp, prr):
            ctx = TraceContext("collect", probes=prr, rewrites={})
            loss = loss_call(pp, b, ctx)
            return loss, ctx.fwd
        (loss, fwd), (pgrads, agrads) = jax.value_and_grad(
            loss_fn, argnums=(0, 1), has_aux=True)(p, pr)
        new_p, new_st, info = opt.update(p, pgrads, st)
        return (loss, fwd, pgrads, agrads, new_p, new_st,
                info.main_grads, info.grad_norm)

    step_c = jax.jit(_step) if jit else _step

    def step(p, st, b):
        with device_ctx(device):
            (loss, fwd, pgrads, agrads, new_p, new_st,
             main_grads, grad_norm) = step_c(p, st, b, probes)
        tr = Trace()
        tr.loss = loss
        tr.grad_norm = grad_norm
        tr.activations = {k: fwd[k] for k in fwd_order}
        tr.act_grads = {k: agrads[k] for k in fwd_order if k in agrads}
        tr.param_grads = flatten_named(pgrads)
        tr.main_grads = flatten_named(main_grads)
        tr.params_post = flatten_named(new_p)
        tr.meta["fwd_order"] = list(fwd_order)
        return tr, new_p, new_st

    return step


# ---------------------------------------------------------------------------
# Fused pair collector (threshold estimation in one compiled call)
# ---------------------------------------------------------------------------

def trace_pair_step(model, params, batch2, opt=None, opt_state=None,
                    collect_act_grads: bool = True, tap_filter=None,
                    jit: bool = True) -> tuple[Trace, Trace]:
    """Collect traces of TWO batches (stacked on a leading axis of size 2 in
    every leaf of ``batch2``) in ONE vmapped, compiled step — the fused path
    of threshold estimation: base and eps-perturbed reference run together
    instead of two serial jit round-trips.
    """
    def loss_call(p, b, ctx):
        loss, _ = model.loss(p, b, ctx=ctx)
        return loss

    return trace_fn_pair(loss_call, params, batch2, opt=opt,
                         opt_state=opt_state,
                         collect_act_grads=collect_act_grads,
                         tap_filter=tap_filter, jit=jit)


def make_pair_collector(loss_call, opt, params, batch, *,
                        collect_act_grads=True, tap_filter=None, jit=True,
                        row_rewrite=None, device=None):
    """Build-once vmapped BASE+PERTURBED pair collection — the single
    source of the stacked two-row reference run.

    ``trace_fn_pair`` calls it once per invocation; the supervised loop's
    ``thresholds.make_pair_estimator`` builds it once and reuses the same
    compiled callable across re-estimation epochs.  ``batch`` is an
    UNSTACKED shape template.  ``row_rewrite(flag, step)`` optionally
    builds a per-row callable-rewrite dict traced into the vmapped step
    (the token-input embedding perturbation: flag 0 on the base row, 1 on
    the perturbed row).

    Returns ``collect(params, opt_state, batch2, step=0) -> (Trace,
    Trace)`` with ``collect.shapes`` / ``collect.fwd_order`` exposing the
    tap discovery; loss/grad_norm stay device scalars (callers that need
    host floats convert).
    """
    batch_t = {k: jnp.asarray(v) for k, v in batch.items()}
    shapes, fwd_order = tap_shapes(loss_call, params, batch_t, None)
    with device_ctx(device):
        probes = _make_probes(shapes, tap_filter, collect_act_grads)

    def one(p, b, flag, step_k, pr):
        def loss_fn(pp, prr):
            rew = row_rewrite(flag, step_k) if row_rewrite is not None else {}
            ctx = TraceContext("rewrite" if rew else "collect", probes=prr,
                               rewrites=rew)
            loss = loss_call(pp, b, ctx)
            return loss, ctx.fwd
        (loss, fwd), (pg, ag) = jax.value_and_grad(
            loss_fn, argnums=(0, 1), has_aux=True)(p, pr)
        return loss, fwd, pg, ag

    def _pair(p, st, b2, flags, step_k, pr):
        loss, fwd, pg, ag = jax.vmap(
            one, in_axes=(None, 0, 0, None, None))(p, b2, flags, step_k, pr)
        if opt is None:
            return loss, fwd, pg, ag, None, None, None
        new_p, _, info = jax.vmap(
            opt.update, in_axes=(None, 0, None))(p, pg, st)
        return loss, fwd, pg, ag, info.main_grads, new_p, info.grad_norm

    pair_c = jax.jit(_pair) if jit else _pair
    flags = jnp.asarray([0.0, 1.0], jnp.float32)

    def collect(p, st, batch2, step: int = 0) -> tuple[Trace, Trace]:
        with device_ctx(device):
            b2 = {k: jnp.asarray(v) for k, v in batch2.items()}
            loss, fwd, pg, ag, mg, new_p, gn = pair_c(p, st, b2, flags,
                                                      jnp.int32(step), probes)
        pg_named = flatten_named(pg)
        mg_named = None if mg is None else flatten_named(mg)
        np_named = None if new_p is None else flatten_named(new_p)
        traces = []
        for i in (0, 1):
            tr = Trace()
            tr.loss = loss[i]
            tr.activations = {k: fwd[k][i] for k in fwd_order}
            tr.act_grads = {k: ag[k][i] for k in fwd_order if k in ag}
            tr.param_grads = {k: v[i] for k, v in pg_named.items()}
            tr.meta["fwd_order"] = list(fwd_order)
            if mg_named is not None:
                tr.main_grads = {k: v[i] for k, v in mg_named.items()}
                tr.params_post = {k: v[i] for k, v in np_named.items()}
                tr.grad_norm = gn[i]
            traces.append(tr)
        return traces[0], traces[1]

    collect.shapes = shapes
    collect.fwd_order = fwd_order
    return collect


def trace_fn_pair(loss_call, params, batch2, opt=None, opt_state=None,
                  collect_act_grads=True, tap_filter=None, jit=True
                  ) -> tuple[Trace, Trace]:
    batch2_j = {k: jnp.asarray(v) for k, v in batch2.items()}
    batch0 = {k: v[0] for k, v in batch2_j.items()}
    collect = make_pair_collector(loss_call, opt, params, batch0,
                                  collect_act_grads=collect_act_grads,
                                  tap_filter=tap_filter, jit=jit)
    st = None
    if opt is not None:
        st = opt_state if opt_state is not None else opt.init(params)
    t0, t1 = collect(params, st, batch2_j)
    for tr in (t0, t1):      # one-shot API contract: host floats
        tr.loss = float(tr.loss)
        if opt is not None:
            tr.grad_norm = float(tr.grad_norm)
    return t0, t1
