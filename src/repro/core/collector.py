"""Trace collector (paper §4.3): runs one training iteration and records

* forward activations of every tapped module (inputs + outputs),
* activation gradients (via zero probes — the functional tensor-hook),
* parameter gradients,
* main (fp32, post-clip) gradients from the optimizer,
* post-step parameters,

as a ``Trace`` of host numpy arrays keyed by canonical tap/param names.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.tap import TraceContext


# ---------------------------------------------------------------------------
# pytree <-> flat named dict
# ---------------------------------------------------------------------------

def _key_str(k) -> str:
    if hasattr(k, "key"):
        return str(k.key)
    if hasattr(k, "idx"):
        return str(k.idx)
    return str(k)


def flatten_named(tree, sep=".") -> dict[str, jax.Array]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    return {sep.join(_key_str(k) for k in path): leaf for path, leaf in flat}


def unflatten_named(names: dict, template):
    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in flat:
        leaves.append(names[".".join(_key_str(k) for k in path)])
    return jax.tree_util.tree_unflatten(treedef, leaves)


# ---------------------------------------------------------------------------
# Trace
# ---------------------------------------------------------------------------

@dataclass
class Trace:
    activations: dict[str, np.ndarray] = field(default_factory=dict)
    act_grads: dict[str, np.ndarray] = field(default_factory=dict)
    param_grads: dict[str, np.ndarray] = field(default_factory=dict)
    main_grads: dict[str, np.ndarray] = field(default_factory=dict)
    params_post: dict[str, np.ndarray] = field(default_factory=dict)
    loss: float = float("nan")
    grad_norm: float = float("nan")
    meta: dict = field(default_factory=dict)

    def section(self, kind: str) -> dict[str, np.ndarray]:
        from repro.core import canonical as C
        return {C.KIND_ACT: self.activations, C.KIND_ACT_GRAD: self.act_grads,
                C.KIND_PARAM_GRAD: self.param_grads,
                C.KIND_MAIN_GRAD: self.main_grads,
                C.KIND_PARAM_POST: self.params_post}[kind]


def _np(tree):
    return {k: np.asarray(v) for k, v in tree.items()}


# ---------------------------------------------------------------------------
# Reference collector (single-device)
# ---------------------------------------------------------------------------

def tap_shapes(loss_callable, params, batch, rewrites=None
               ) -> tuple[dict, list[str]]:
    """Pass 1: eval_shape the forward to enumerate tap names/shapes.

    Also returns the tap names in FORWARD ORDER (jax sorts dict pytrees, but
    propagation-order bug localization needs execution order)."""
    order: list[str] = []

    def f(params):
        ctx = TraceContext("rewrite" if rewrites else "collect",
                           rewrites=rewrites or {})
        loss_callable(params, batch, ctx)
        order.clear()
        order.extend(ctx.fwd.keys())
        return ctx.fwd

    return jax.eval_shape(f, params), order


def trace_train_step(model, params, batch, opt=None, opt_state=None,
                     rewrites: Optional[dict] = None,
                     collect_act_grads: bool = True,
                     tap_filter: Optional[Callable[[str], bool]] = None,
                     jit: bool = True) -> tuple[Trace, dict, Optional[dict]]:
    """Run ONE training iteration of the single-device reference with full
    trace collection.  Returns (trace, new_params, new_opt_state).

    ``rewrites``: {tap_name: np/jnp array} — overwrite module inputs
    (localization mode / threshold estimation).
    """
    def loss_call(p, b, ctx):
        loss, _ = model.loss(p, b, ctx=ctx)
        return loss

    return trace_fn_step(loss_call, params, batch, opt=opt,
                         opt_state=opt_state, rewrites=rewrites,
                         collect_act_grads=collect_act_grads,
                         tap_filter=tap_filter, jit=jit)


def trace_fn_step(loss_call, params, batch, opt=None, opt_state=None,
                  rewrites=None, collect_act_grads=True, tap_filter=None,
                  jit=True) -> tuple[Trace, dict, Optional[dict]]:
    """Generic collector over any ``loss_call(params, batch, ctx) -> loss``.

    Used for both the reference model and candidate step functions that
    compute loss differently (e.g. pipeline-partitioned execution).
    """
    rewrites_j = (None if rewrites is None
                  else {k: jnp.asarray(v) for k, v in rewrites.items()})
    shapes, fwd_order = tap_shapes(loss_call, params, batch, rewrites_j)
    mode = "rewrite" if rewrites_j else "collect"

    if collect_act_grads:
        probes = {k: jnp.zeros(s.shape, jnp.float32)
                  for k, s in shapes.items()
                  if (tap_filter is None or tap_filter(k))
                  and jnp.issubdtype(s.dtype, jnp.floating)}
    else:
        probes = {}

    def loss_fn(p, probes):
        ctx = TraceContext(mode, probes=probes, rewrites=rewrites_j or {})
        loss = loss_call(p, batch, ctx)
        return loss, ctx.fwd

    def step(p, probes):
        (loss, fwd), (pgrads, agrads) = jax.value_and_grad(
            loss_fn, argnums=(0, 1), has_aux=True)(p, probes)
        return loss, fwd, pgrads, agrads

    step_c = jax.jit(step) if jit else step
    loss, fwd, pgrads, agrads = step_c(params, probes)

    tr = Trace()
    tr.loss = float(loss)
    tr.activations = {k: np.asarray(fwd[k]) for k in fwd_order}
    tr.act_grads = {k: np.asarray(agrads[k]) for k in fwd_order
                    if k in agrads}
    tr.param_grads = _np(flatten_named(pgrads))
    tr.meta["fwd_order"] = list(fwd_order)

    new_params, new_state = params, opt_state
    if opt is not None:
        upd = jax.jit(opt.update) if jit else opt.update
        new_params, new_state, info = upd(params, pgrads, opt_state)
        tr.main_grads = _np(flatten_named(info.main_grads))
        tr.params_post = _np(flatten_named(new_params))
        tr.grad_norm = float(info.grad_norm)
    return tr, new_params, new_state
