"""Tensor merger: rebuild logical full tensors from shards (paper §4.1, §4.4).

Given rank-local shards plus the annotation-derived shard mapping, the merger

* reassembles the logical full tensor;
* verifies coverage — **no overlap, no omission** of any element;
* verifies **replica consistency**: shards from ranks that map to identical
  slices (e.g. main gradients across DP ranks when ZeRO is off) must agree;
  a disagreement is reported as a *conflicting tensor* (the classic missing
  all-reduce signature, paper §4.4).

``merge_jax_array`` additionally cross-checks a ``jax.Array``'s actual device
layout against the user's annotation, catching "the framework sharded this
differently than you told me" bugs before any value comparison happens.

``merge_microbatch_traces`` is the **per-rank trace path** (paper Fig 5):
given the stage-local, per-microbatch traces a real pipeline schedule emits,
it concatenates the microbatch axis, canonicalizes stage-local layer names
via the per-stage ``stage_layer_table`` renaming, accumulates per-microbatch
parameter-gradient contributions, and verifies (stage, microbatch) coverage —
no microbatch contributed twice, none missing — before any value comparison.
"""
from __future__ import annotations

import itertools
import re
from dataclasses import dataclass, field

import numpy as np

from repro.core.annotations import ShardSpec, slices_for_rank

# relative tolerance for replica agreement: replicas are produced by the SAME
# reduction on each rank, so they should match to ~machine epsilon.
REPLICA_RTOL = 1e-5


@dataclass
class MergeReport:
    ok: bool = True
    conflicts: list = field(default_factory=list)   # replica disagreements
    overlap: int = 0
    omission: int = 0
    layout_mismatches: list = field(default_factory=list)
    rank_problems: list = field(default_factory=list)  # per-rank trace merge

    def problems(self) -> list[str]:
        out = []
        if self.overlap:
            out.append(f"{self.overlap} elements covered more than once")
        if self.omission:
            out.append(f"{self.omission} elements not covered by any shard")
        for c in self.conflicts:
            out.append(f"replica conflict at coords {c['coords']} vs "
                       f"{c['ref_coords']}: rel_err={c['rel_err']:.3e}")
        for m in self.layout_mismatches:
            out.append(f"layout mismatch at coords {m['coords']}: annotation "
                       f"says {m['expected']}, array is {m['actual']}")
        out.extend(self.rank_problems)
        return out


def merge_shards(shards: dict[tuple, np.ndarray], spec: ShardSpec,
                 sizes: dict[str, int], global_shape: tuple[int, ...],
                 replica_rtol: float = REPLICA_RTOL
                 ) -> tuple[np.ndarray, MergeReport]:
    """shards: {coords tuple (in AXES order of `sizes` keys) -> local array}.

    ``sizes`` maps axis name -> degree; coords tuples are keyed in the same
    order as ``sizes``.
    """
    axes = list(sizes)
    report = MergeReport()
    full = np.zeros(global_shape, np.float64)
    cover = np.zeros(global_shape, np.int16)
    seen: dict[tuple, tuple] = {}   # frozen slice key -> (coords, array)

    for coords_t, arr in shards.items():
        coords = dict(zip(axes, coords_t))
        frags = slices_for_rank(spec, global_shape, sizes, coords)
        key = tuple((s.start, s.stop) for f in frags for s in f)
        if key in seen:
            ref_coords, ref_arr = seen[key]
            denom = np.linalg.norm(ref_arr.astype(np.float64))
            err = np.linalg.norm(arr.astype(np.float64)
                                 - ref_arr.astype(np.float64))
            rel = err / denom if denom > 0 else err
            if rel > replica_rtol:
                report.conflicts.append(
                    {"coords": coords_t, "ref_coords": ref_coords,
                     "rel_err": float(rel)})
                report.ok = False
            continue
        seen[key] = (coords_t, arr)
        # place fragments: multi-fragment shards are concatenated along the
        # cp dim in chunk order, so walk them in the same order.
        off = 0
        cdim = (spec.cp_dim % len(global_shape)
                if (spec.cp_mode == "zigzag" and spec.cp_dim is not None)
                else None)
        for f in frags:
            if cdim is None:
                piece = arr
            else:
                ext = f[cdim].stop - f[cdim].start
                idx = [slice(None)] * arr.ndim
                idx[cdim] = slice(off, off + ext)
                piece = arr[tuple(idx)]
                off += ext
            want = tuple(s.stop - s.start for s in f)
            if piece.shape != want:
                # shard shape contradicts the annotation-derived mapping
                report.layout_mismatches.append(
                    {"coords": coords_t, "expected": want,
                     "actual": piece.shape})
                report.ok = False
                continue
            full[f] += piece.astype(np.float64)
            cover[f] += 1
    report.overlap = int(np.sum(cover > 1))
    report.omission = int(np.sum(cover == 0))
    if report.overlap or report.omission:
        report.ok = False
    return full.astype(np.float32), report


def merge_jax_array(arr, spec: ShardSpec, mesh_axes: dict[str, str],
                    replica_rtol: float = REPLICA_RTOL
                    ) -> tuple[np.ndarray, MergeReport]:
    """Rebuild + verify a sharded ``jax.Array`` against the annotation.

    ``mesh_axes`` maps parallel-axis name ("tp", "dp", ...) to the mesh axis
    name it runs on (e.g. {"dp": "data", "tp": "model"}).
    """
    mesh = arr.sharding.mesh
    sizes = {p: int(mesh.shape[m]) for p, m in mesh_axes.items()}
    report = MergeReport()
    shards = {}
    for sh in arr.addressable_shards:
        didx = {m: int(i) for m, i in zip(
            mesh.axis_names, np.argwhere(
                np.asarray(mesh.devices) == sh.device)[0])}
        coords_t = tuple(didx[mesh_axes[p]] for p in sizes)
        coords = dict(zip(sizes, coords_t))
        expected = slices_for_rank(spec, arr.shape, sizes, coords)
        actual = tuple(
            slice(s.start or 0, s.stop if s.stop is not None else dim)
            for s, dim in zip(sh.index, arr.shape))
        if len(expected) == 1 and expected[0] != actual:
            report.layout_mismatches.append(
                {"coords": coords_t, "expected": expected[0],
                 "actual": actual})
            report.ok = False
        shards[coords_t] = np.asarray(sh.data)
    full, rep2 = merge_shards(shards, spec, sizes, arr.shape, replica_rtol)
    rep2.layout_mismatches.extend(report.layout_mismatches)
    rep2.ok = rep2.ok and report.ok
    return full, rep2


# ---------------------------------------------------------------------------
# Per-rank trace merging (real pipeline schedules, paper Fig 5)
# ---------------------------------------------------------------------------

_LAYER_RE = re.compile(r"^layers\.(\d+)(.*)$")


def canonical_stage_name(name: str, table: list[tuple[int, int]]) -> str:
    """Stage-LOCAL tap/param name -> canonical (global) name via the stage's
    ``(executed, canonical)`` table — the renaming a rank-local trace needs
    before it can align with the single-device reference (paper Fig 5).
    Non-layer names (embedding, final norm, LM head) pass through."""
    m = _LAYER_RE.match(name)
    if not m:
        return name
    local = int(m.group(1))
    if local >= len(table):
        raise KeyError(f"local layer {local} outside a stage table of "
                       f"{len(table)} entries")
    return f"layers.{table[local][1]}{m.group(2)}"


def merge_microbatch_traces(records, tables, n_microbatches: int,
                            place=None):
    """Merge per-(stage, microbatch) rank-local traces into ONE
    reference-shaped trace.

    ``records``: iterable of ``(stage, microbatch, Trace)`` — forward ops
    contribute ``activations`` (plus per-stage ``meta['fwd_order']``),
    backward ops contribute ``act_grads`` and per-microbatch
    ``param_grads`` contributions.  ``tables``: per-stage
    ``(executed, canonical)`` renaming (``parallel.pp1f1b.stage_tables``).
    ``place``: optional device/sharding the merged leaves are gathered to
    (the controller the checker runs on); without it, leaves must already
    be colocated per stage.

    The merge verifies per-rank coverage before any value comparison can
    happen: every (stage, name) must be contributed by every microbatch
    exactly once (overlap/omission otherwise), canonicalized names must
    stay unique across stages within a kind — replicated non-layer params
    (tied embeddings on both pipeline ends) instead SUM, the explicit
    tied-embedding reduction — and activations/activation gradients are
    concatenated along the microbatch (batch) axis in microbatch order
    while parameter gradients accumulate across microbatches.

    Returns ``(merged_trace, MergeReport)``; the report also rides along as
    ``merged.meta['merge_report']`` so downstream checkers surface its
    problems with the step report.
    """
    import jax

    from repro.core import canonical as C
    from repro.core.collector import Section, Trace

    S, M = len(tables), n_microbatches
    report = MergeReport()

    def problem(msg):
        report.rank_problems.append(msg)
        report.ok = False

    per: dict = {C.KIND_ACT: {}, C.KIND_ACT_GRAD: {},
                 C.KIND_PARAM_GRAD: {}}
    fwd_orders: dict = {}
    for stage, mb, tr in records:
        if not (0 <= stage < S and 0 <= mb < M):
            problem(f"record (stage {stage}, mb {mb}) outside the "
                    f"{S}x{M} schedule grid")
            continue
        if len(tr.activations) and stage not in fwd_orders:
            fwd_orders[stage] = list(tr.meta.get("fwd_order")
                                     or tr.activations)
        for kind, acc in per.items():
            sec = tr.section(kind)
            for name in sec:
                by_mb = acc.setdefault((stage, name), {})
                if mb in by_mb:
                    report.overlap += 1
                    problem(f"{kind} {name}: (stage {stage}, mb {mb}) "
                            f"contributed twice")
                    continue
                by_mb[mb] = sec.raw(name)

    def gather(x):
        return jax.device_put(x, place) if place is not None else x

    def full_coverage(kind, stage, name, by_mb) -> bool:
        missing = [m for m in range(M) if m not in by_mb]
        if missing:
            report.omission += len(missing)
            problem(f"{kind} {name}: stage {stage} missing "
                    f"microbatch(es) {missing}")
            return False
        return True

    merged = Trace()
    # activations / activation grads: concat along the microbatch axis
    for kind in (C.KIND_ACT, C.KIND_ACT_GRAD):
        out = merged.section(kind)
        for stage in sorted({s for s, _ in per[kind]}):
            valid = {name: by_mb
                     for (s, name), by_mb in per[kind].items()
                     if s == stage
                     and full_coverage(kind, stage, name, by_mb)}
            if not valid:
                continue
            cat = Section.concat(
                [Section({n: gather(valid[n][m]) for n in valid})
                 for m in range(M)], axis=0)
            for name in cat:
                canon = canonical_stage_name(name, tables[stage])
                if canon in out:
                    problem(f"{kind} {canon}: produced by more than one "
                            f"stage after canonical renaming")
                    continue
                out[canon] = cat.raw(name)
    # parameter grads: accumulate the per-microbatch contributions
    pg = merged.param_grads
    for (stage, name) in sorted(per[C.KIND_PARAM_GRAD],
                                key=lambda sn: sn[0]):
        by_mb = per[C.KIND_PARAM_GRAD][(stage, name)]
        if not full_coverage(C.KIND_PARAM_GRAD, stage, name, by_mb):
            continue
        total = gather(by_mb[0])
        for m in range(1, M):
            total = total + gather(by_mb[m])
        canon = canonical_stage_name(name, tables[stage])
        if canon in pg:
            if name.startswith("layers."):
                problem(f"param_grad {canon}: produced by more than one "
                        f"stage after canonical renaming")
                continue
            pg[canon] = pg.raw(canon) + total   # tied-embedding reduction
        else:
            pg[canon] = total
    order = []
    for stage in sorted(fwd_orders):
        order.extend(canonical_stage_name(n, tables[stage])
                     for n in fwd_orders[stage])
    merged.meta["fwd_order"] = order
    merged.meta["merge_report"] = report
    return merged, report
