"""Tensor merger: rebuild logical full tensors from shards (paper §4.1, §4.4).

Given rank-local shards plus the annotation-derived shard mapping, the merger

* reassembles the logical full tensor;
* verifies coverage — **no overlap, no omission** of any element;
* verifies **replica consistency**: shards from ranks that map to identical
  slices (e.g. main gradients across DP ranks when ZeRO is off) must agree;
  a disagreement is reported as a *conflicting tensor* (the classic missing
  all-reduce signature, paper §4.4).

``merge_jax_array`` additionally cross-checks a ``jax.Array``'s actual device
layout against the user's annotation, catching "the framework sharded this
differently than you told me" bugs before any value comparison happens.

``merge_microbatch_traces`` is the **per-rank trace path** (paper Fig 5):
given the stage-local, per-microbatch traces a real pipeline schedule emits,
it concatenates the microbatch axis, canonicalizes stage-local layer names
via the per-stage ``stage_layer_table`` renaming, accumulates per-microbatch
parameter-gradient contributions, and verifies (stage, microbatch) coverage —
no microbatch contributed twice, none missing — before any value comparison.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

import numpy as np

from repro.core.annotations import ShardSpec, slices_for_rank

# relative tolerance for replica agreement: replicas are produced by the SAME
# reduction on each rank, so they should match to ~machine epsilon.
REPLICA_RTOL = 1e-5


@dataclass
class MergeReport:
    ok: bool = True
    conflicts: list = field(default_factory=list)   # replica disagreements
    overlap: int = 0
    omission: int = 0
    layout_mismatches: list = field(default_factory=list)
    rank_problems: list = field(default_factory=list)  # per-rank trace merge

    def problems(self) -> list[str]:
        out = []
        if self.overlap:
            out.append(f"{self.overlap} elements covered more than once")
        if self.omission:
            out.append(f"{self.omission} elements not covered by any shard")
        for c in self.conflicts:
            out.append(f"replica conflict at coords {c['coords']} vs "
                       f"{c['ref_coords']}: rel_err={c['rel_err']:.3e}")
        for m in self.layout_mismatches:
            out.append(f"layout mismatch at coords {m['coords']}: annotation "
                       f"says {m['expected']}, array is {m['actual']}")
        out.extend(self.rank_problems)
        return out


def merge_shards(shards: dict[tuple, np.ndarray], spec: ShardSpec,
                 sizes: dict[str, int], global_shape: tuple[int, ...],
                 replica_rtol: float = REPLICA_RTOL
                 ) -> tuple[np.ndarray, MergeReport]:
    """shards: {coords tuple (in AXES order of `sizes` keys) -> local array}.

    ``sizes`` maps axis name -> degree; coords tuples are keyed in the same
    order as ``sizes``.
    """
    axes = list(sizes)
    report = MergeReport()
    full = np.zeros(global_shape, np.float64)
    cover = np.zeros(global_shape, np.int16)
    seen: dict[tuple, tuple] = {}   # frozen slice key -> (coords, array)

    for coords_t, arr in shards.items():
        coords = dict(zip(axes, coords_t))
        frags = slices_for_rank(spec, global_shape, sizes, coords)
        key = tuple((s.start, s.stop) for f in frags for s in f)
        if key in seen:
            ref_coords, ref_arr = seen[key]
            denom = np.linalg.norm(ref_arr.astype(np.float64))
            err = np.linalg.norm(arr.astype(np.float64)
                                 - ref_arr.astype(np.float64))
            rel = err / denom if denom > 0 else err
            if rel > replica_rtol:
                report.conflicts.append(
                    {"coords": coords_t, "ref_coords": ref_coords,
                     "rel_err": float(rel)})
                report.ok = False
            continue
        seen[key] = (coords_t, arr)
        # place fragments: multi-fragment shards are concatenated along the
        # cp dim in chunk order, so walk them in the same order.
        off = 0
        cdim = (spec.cp_dim % len(global_shape)
                if (spec.cp_mode == "zigzag" and spec.cp_dim is not None)
                else None)
        for f in frags:
            if cdim is None:
                piece = arr
            else:
                ext = f[cdim].stop - f[cdim].start
                idx = [slice(None)] * arr.ndim
                idx[cdim] = slice(off, off + ext)
                piece = arr[tuple(idx)]
                off += ext
            want = tuple(s.stop - s.start for s in f)
            if piece.shape != want:
                # shard shape contradicts the annotation-derived mapping
                report.layout_mismatches.append(
                    {"coords": coords_t, "expected": want,
                     "actual": piece.shape})
                report.ok = False
                continue
            full[f] += piece.astype(np.float64)
            cover[f] += 1
    report.overlap = int(np.sum(cover > 1))
    report.omission = int(np.sum(cover == 0))
    if report.overlap or report.omission:
        report.ok = False
    return full.astype(np.float32), report


def merge_jax_array(arr, spec: ShardSpec, mesh_axes: dict[str, str],
                    replica_rtol: float = REPLICA_RTOL
                    ) -> tuple[np.ndarray, MergeReport]:
    """Rebuild + verify a sharded ``jax.Array`` against the annotation.

    ``mesh_axes`` maps parallel-axis name ("tp", "dp", ...) to the mesh axis
    name it runs on (e.g. {"dp": "data", "tp": "model"}).
    """
    mesh = arr.sharding.mesh
    sizes = {p: int(mesh.shape[m]) for p, m in mesh_axes.items()}
    report = MergeReport()
    shards = {}
    for sh in arr.addressable_shards:
        didx = {m: int(i) for m, i in zip(
            mesh.axis_names, np.argwhere(
                np.asarray(mesh.devices) == sh.device)[0])}
        coords_t = tuple(didx[mesh_axes[p]] for p in sizes)
        coords = dict(zip(sizes, coords_t))
        expected = slices_for_rank(spec, arr.shape, sizes, coords)
        actual = tuple(
            slice(s.start or 0, s.stop if s.stop is not None else dim)
            for s, dim in zip(sh.index, arr.shape))
        if len(expected) == 1 and expected[0] != actual:
            report.layout_mismatches.append(
                {"coords": coords_t, "expected": expected[0],
                 "actual": actual})
            report.ok = False
        shards[coords_t] = np.asarray(sh.data)
    full, rep2 = merge_shards(shards, spec, sizes, arr.shape, replica_rtol)
    rep2.layout_mismatches.extend(report.layout_mismatches)
    rep2.ok = rep2.ok and report.ok
    return full, rep2


# ---------------------------------------------------------------------------
# Per-rank trace merging (real pipeline schedules, paper Fig 5)
# ---------------------------------------------------------------------------

_LAYER_RE = re.compile(r"^layers\.(\d+)(.*)$")


def canonical_stage_name(name: str, table: list[tuple[int, int]]) -> str:
    """Stage-LOCAL tap/param name -> canonical (global) name via the stage's
    ``(executed, canonical)`` table — the renaming a rank-local trace needs
    before it can align with the single-device reference (paper Fig 5).
    Non-layer names (embedding, final norm, LM head) pass through."""
    m = _LAYER_RE.match(name)
    if not m:
        return name
    local = int(m.group(1))
    if local >= len(table):
        raise KeyError(f"local layer {local} outside a stage table of "
                       f"{len(table)} entries")
    return f"layers.{table[local][1]}{m.group(2)}"


def merge_microbatch_traces(records, tables, n_microbatches: int,
                            place=None):
    """Merge per-(stage, microbatch) rank-local traces into ONE
    reference-shaped trace.

    ``records``: iterable of ``(stage, microbatch, Trace)`` — forward ops
    contribute ``activations`` (plus per-stage ``meta['fwd_order']``),
    backward ops contribute ``act_grads`` and per-microbatch
    ``param_grads`` contributions.  ``tables``: per-stage
    ``(executed, canonical)`` renaming (``parallel.pp1f1b.stage_tables``).
    ``place``: optional device/sharding the merged leaves are gathered to
    (the controller the checker runs on); without it, leaves must already
    be colocated per stage.

    The merge verifies per-rank coverage before any value comparison can
    happen: every (stage, name) must be contributed by every microbatch
    exactly once (overlap/omission otherwise), canonicalized names must
    stay unique across stages within a kind — replicated non-layer params
    (tied embeddings on both pipeline ends) instead SUM, the explicit
    tied-embedding reduction — and activations/activation gradients are
    concatenated along the microbatch (batch) axis in microbatch order
    while parameter gradients accumulate across microbatches.

    Returns ``(merged_trace, MergeReport)``; the report also rides along as
    ``merged.meta['merge_report']`` so downstream checkers surface its
    problems with the step report.
    """
    import jax

    from repro.core import canonical as C
    from repro.core.collector import Section, Trace

    S, M = len(tables), n_microbatches
    report = MergeReport()

    def problem(msg):
        report.rank_problems.append(msg)
        report.ok = False

    per: dict = {C.KIND_ACT: {}, C.KIND_ACT_GRAD: {},
                 C.KIND_PARAM_GRAD: {}}
    fwd_orders: dict = {}
    for stage, mb, tr in records:
        if not (0 <= stage < S and 0 <= mb < M):
            problem(f"record (stage {stage}, mb {mb}) outside the "
                    f"{S}x{M} schedule grid")
            continue
        if len(tr.activations) and stage not in fwd_orders:
            fwd_orders[stage] = list(tr.meta.get("fwd_order")
                                     or tr.activations)
        for kind, acc in per.items():
            sec = tr.section(kind)
            for name in sec:
                by_mb = acc.setdefault((stage, name), {})
                if mb in by_mb:
                    report.overlap += 1
                    problem(f"{kind} {name}: (stage {stage}, mb {mb}) "
                            f"contributed twice")
                    continue
                by_mb[mb] = sec.raw(name)

    def gather(x):
        return jax.device_put(x, place) if place is not None else x

    def full_coverage(kind, stage, name, by_mb) -> bool:
        missing = [m for m in range(M) if m not in by_mb]
        if missing:
            report.omission += len(missing)
            problem(f"{kind} {name}: stage {stage} missing "
                    f"microbatch(es) {missing}")
            return False
        return True

    merged = Trace()
    # activations / activation grads: concat along the microbatch axis
    for kind in (C.KIND_ACT, C.KIND_ACT_GRAD):
        out = merged.section(kind)
        for stage in sorted({s for s, _ in per[kind]}):
            valid = {name: by_mb
                     for (s, name), by_mb in per[kind].items()
                     if s == stage
                     and full_coverage(kind, stage, name, by_mb)}
            if not valid:
                continue
            cat = Section.concat(
                [Section({n: gather(valid[n][m]) for n in valid})
                 for m in range(M)], axis=0)
            for name in cat:
                canon = canonical_stage_name(name, tables[stage])
                if canon in out:
                    problem(f"{kind} {canon}: produced by more than one "
                            f"stage after canonical renaming")
                    continue
                out[canon] = cat.raw(name)
    # parameter grads: accumulate the per-microbatch contributions
    pg = merged.param_grads
    for (stage, name) in sorted(per[C.KIND_PARAM_GRAD],
                                key=lambda sn: sn[0]):
        by_mb = per[C.KIND_PARAM_GRAD][(stage, name)]
        if not full_coverage(C.KIND_PARAM_GRAD, stage, name, by_mb):
            continue
        total = gather(by_mb[0])
        for m in range(1, M):
            total = total + gather(by_mb[m])
        canon = canonical_stage_name(name, tables[stage])
        if canon in pg:
            if name.startswith("layers."):
                problem(f"param_grad {canon}: produced by more than one "
                        f"stage after canonical renaming")
                continue
            pg[canon] = pg.raw(canon) + total   # tied-embedding reduction
        else:
            pg[canon] = total
    order = []
    for stage in sorted(fwd_orders):
        order.extend(canonical_stage_name(n, tables[stage])
                     for n in fwd_orders[stage])
    merged.meta["fwd_order"] = order
    merged.meta["merge_report"] = report
    return merged, report


# ---------------------------------------------------------------------------
# Plan-compiled per-rank merging (the supervised hot path)
# ---------------------------------------------------------------------------
#
# ``merge_microbatch_traces`` re-derives static facts every step: the stage
# tables never change, the canonical renaming never changes, the coverage
# grid of a fixed schedule never changes, and the tied-param groups never
# change — yet the per-step Python loop walks every (stage, microbatch,
# name) cell, verifies it, renames it and issues one eager device op (gather
# / concat / add) per cell.  ``MergePlan`` factors all of that out:
#
# * **build once** — run the exact structural walk of the full merge on a
#   template record set, recording the output layout (per-kind name order,
#   canonical renames, tied-param groups), the coverage verdict (the
#   ``MergeReport`` of any record set with this structure) and the per-stage
#   input indexing;
# * **execute per step** — one cheap record-set signature check, then ONE
#   jitted pack per stage (stacked microbatch concat + fused param-grad
#   accumulation, running on the stage's own device) and one bulk transfer
#   of the packed outputs to the controller; the merged sections are then
#   pure renames of the packed leaves.
#
# Execution is numerically IDENTICAL to the full merge: concatenation is
# exact, and the per-microbatch gradient accumulation keeps the same
# left-to-right chain (XLA does not reassociate float adds).  A record set
# whose structure deviates from the plan (different names, coverage, or
# grid) falls back to the full merge, so structural bugs keep their exact
# diagnostics.


class MergePlan:
    """Build-once merge plan over a fixed per-rank record structure.

    ``build(records, tables, n_microbatches, place=...)`` derives the plan
    from a template record set (typically the first step's); ``execute``
    then merges any same-structured record set in a handful of device
    dispatches.  ``stage_param_grads`` holds, after ``execute``, the
    per-stage accumulated parameter gradients under their stage-LOCAL names
    (already on ``place``) — the 1F1B engine reuses them for the
    executed-index global gradient tree instead of re-accumulating.
    """

    def __init__(self, tables, n_microbatches: int, place=None):
        self.tables = tables
        self.M = n_microbatches
        self.place = place
        self.signature = None
        self._problems: list[str] = []
        self._overlap = self._omission = 0
        self._fwd_order: list[str] = []
        # output layout: [(kind, stage, local name, canonical name)] in the
        # full merge's output order; tied groups: [(canon, [(stage, name)])]
        self._cat_out: list = []
        self._pg_out: list = []
        # per-stage pack inputs: stage -> ([(kind, name, [rec_idx per mb])],
        #                                  [(name, [rec_idx per mb])])
        self._stage_cat: dict = {}
        self._stage_pg: dict = {}
        self._pack = None
        self.stage_param_grads: dict | None = None
        self.executions = 0
        self.fallbacks = 0

    # ---- structural walk (mirrors merge_microbatch_traces exactly) --------
    @staticmethod
    def _sig_of(records) -> tuple:
        return tuple((stage, mb, tuple(tr.activations), tuple(tr.act_grads),
                      tuple(tr.param_grads)) for stage, mb, tr in records)

    @classmethod
    def build(cls, records, tables, n_microbatches: int, place=None
              ) -> "MergePlan":
        from repro.core import canonical as C

        records = list(records)
        plan = cls(tables, n_microbatches, place)
        plan.signature = cls._sig_of(records)
        S, M = len(tables), n_microbatches

        def problem(msg):
            plan._problems.append(msg)

        per: dict = {C.KIND_ACT: {}, C.KIND_ACT_GRAD: {},
                     C.KIND_PARAM_GRAD: {}}
        fwd_orders: dict = {}
        for idx, (stage, mb, tr) in enumerate(records):
            if not (0 <= stage < S and 0 <= mb < M):
                problem(f"record (stage {stage}, mb {mb}) outside the "
                        f"{S}x{M} schedule grid")
                continue
            if len(tr.activations) and stage not in fwd_orders:
                fwd_orders[stage] = list(tr.meta.get("fwd_order")
                                         or tr.activations)
            for kind, acc in per.items():
                for name in tr.section(kind):
                    by_mb = acc.setdefault((stage, name), {})
                    if mb in by_mb:
                        plan._overlap += 1
                        problem(f"{kind} {name}: (stage {stage}, mb {mb}) "
                                f"contributed twice")
                        continue
                    by_mb[mb] = idx

        def full_coverage(kind, stage, name, by_mb) -> bool:
            missing = [m for m in range(M) if m not in by_mb]
            if missing:
                plan._omission += len(missing)
                problem(f"{kind} {name}: stage {stage} missing "
                        f"microbatch(es) {missing}")
                return False
            return True

        for kind in (C.KIND_ACT, C.KIND_ACT_GRAD):
            out_names: set = set()
            for stage in sorted({s for s, _ in per[kind]}):
                valid = {name: by_mb
                         for (s, name), by_mb in per[kind].items()
                         if s == stage
                         and full_coverage(kind, stage, name, by_mb)}
                for name, by_mb in valid.items():
                    canon = canonical_stage_name(name, tables[stage])
                    if canon in out_names:
                        problem(f"{kind} {canon}: produced by more than one "
                                f"stage after canonical renaming")
                        continue
                    out_names.add(canon)
                    plan._cat_out.append((kind, stage, name, canon))
                    plan._stage_cat.setdefault(stage, []).append(
                        (kind, name, [by_mb[m] for m in range(M)]))
        pg_groups: dict = {}
        for (stage, name) in sorted(per[C.KIND_PARAM_GRAD],
                                    key=lambda sn: sn[0]):
            by_mb = per[C.KIND_PARAM_GRAD][(stage, name)]
            if not full_coverage(C.KIND_PARAM_GRAD, stage, name, by_mb):
                continue
            canon = canonical_stage_name(name, tables[stage])
            if canon in pg_groups and name.startswith("layers."):
                problem(f"param_grad {canon}: produced by more than one "
                        f"stage after canonical renaming")
                continue
            if canon not in pg_groups:
                plan._pg_out.append(canon)
            pg_groups.setdefault(canon, []).append((stage, name))
            plan._stage_pg.setdefault(stage, []).append(
                (name, [by_mb[m] for m in range(M)]))
        plan._pg_groups = pg_groups
        order = []
        for stage in sorted(fwd_orders):
            order.extend(canonical_stage_name(n, tables[stage])
                         for n in fwd_orders[stage])
        plan._fwd_order = order
        return plan

    # ---- per-step execution ------------------------------------------------
    @property
    def ok(self) -> bool:
        return not self._problems

    def report(self) -> MergeReport:
        """A fresh MergeReport carrying this structure's (static) verdict."""
        return MergeReport(ok=not self._problems,
                           overlap=self._overlap, omission=self._omission,
                           rank_problems=list(self._problems))

    def matches(self, records) -> bool:
        return self._sig_of(records) == self.signature

    def _packer(self):
        if self._pack is None:
            import functools

            import jax
            import jax.numpy as jnp

            def pack(cats, pgs):
                return ([jnp.concatenate(xs, axis=0) for xs in cats],
                        [xs[0] if len(xs) == 1
                         else functools.reduce(jnp.add, xs) for xs in pgs])

            # per-plan jit wrapper: each plan keeps its own trace cache, so
            # plans over different structures never thrash one another
            self._pack = jax.jit(pack)
        return self._pack

    def execute(self, records):
        """Merge one record set.  Same-structured sets take the compiled
        path; anything else falls back to the full (verifying) merge."""
        import jax

        from repro.core.collector import Trace

        records = list(records)
        if not self.matches(records):
            self.fallbacks += 1
            self.stage_param_grads = None
            return merge_microbatch_traces(records, self.tables, self.M,
                                           place=self.place)
        self.executions += 1
        pack = self._packer()
        packed_cat: dict = {}
        packed_pg: dict = {}
        for stage in sorted(set(self._stage_cat) | set(self._stage_pg)):
            cats = [[records[i][2].section(kind).raw(name) for i in idxs]
                    for kind, name, idxs in self._stage_cat.get(stage, [])]
            pgs = [[records[i][2].param_grads.raw(name) for i in idxs]
                   for name, idxs in self._stage_pg.get(stage, [])]
            out_c, out_p = pack(cats, pgs)
            if self.place is not None:
                out_c, out_p = jax.device_put((out_c, out_p), self.place)
            for (kind, name, _), leaf in zip(self._stage_cat.get(stage, []),
                                             out_c):
                packed_cat[(kind, stage, name)] = leaf
            for (name, _), leaf in zip(self._stage_pg.get(stage, []), out_p):
                packed_pg[(stage, name)] = leaf

        merged = Trace()
        for kind, stage, name, canon in self._cat_out:
            merged.section(kind)[canon] = packed_cat[(kind, stage, name)]
        pg = merged.param_grads
        for canon in self._pg_out:
            group = self._pg_groups[canon]
            total = packed_pg[group[0]]
            for sn in group[1:]:
                total = total + packed_pg[sn]   # tied-embedding reduction
            pg[canon] = total
        self.stage_param_grads = dict(packed_pg)
        report = self.report()
        merged.meta["fwd_order"] = list(self._fwd_order)
        merged.meta["merge_report"] = report
        return merged, report
