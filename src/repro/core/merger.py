"""Tensor merger: rebuild logical full tensors from shards (paper §4.1, §4.4).

Given rank-local shards plus the annotation-derived shard mapping, the merger

* reassembles the logical full tensor;
* verifies coverage — **no overlap, no omission** of any element;
* verifies **replica consistency**: shards from ranks that map to identical
  slices (e.g. main gradients across DP ranks when ZeRO is off) must agree;
  a disagreement is reported as a *conflicting tensor* (the classic missing
  all-reduce signature, paper §4.4).

``merge_jax_array`` additionally cross-checks a ``jax.Array``'s actual device
layout against the user's annotation, catching "the framework sharded this
differently than you told me" bugs before any value comparison happens.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field

import numpy as np

from repro.core.annotations import ShardSpec, slices_for_rank

# relative tolerance for replica agreement: replicas are produced by the SAME
# reduction on each rank, so they should match to ~machine epsilon.
REPLICA_RTOL = 1e-5


@dataclass
class MergeReport:
    ok: bool = True
    conflicts: list = field(default_factory=list)   # replica disagreements
    overlap: int = 0
    omission: int = 0
    layout_mismatches: list = field(default_factory=list)

    def problems(self) -> list[str]:
        out = []
        if self.overlap:
            out.append(f"{self.overlap} elements covered more than once")
        if self.omission:
            out.append(f"{self.omission} elements not covered by any shard")
        for c in self.conflicts:
            out.append(f"replica conflict at coords {c['coords']} vs "
                       f"{c['ref_coords']}: rel_err={c['rel_err']:.3e}")
        for m in self.layout_mismatches:
            out.append(f"layout mismatch at coords {m['coords']}: annotation "
                       f"says {m['expected']}, array is {m['actual']}")
        return out


def merge_shards(shards: dict[tuple, np.ndarray], spec: ShardSpec,
                 sizes: dict[str, int], global_shape: tuple[int, ...],
                 replica_rtol: float = REPLICA_RTOL
                 ) -> tuple[np.ndarray, MergeReport]:
    """shards: {coords tuple (in AXES order of `sizes` keys) -> local array}.

    ``sizes`` maps axis name -> degree; coords tuples are keyed in the same
    order as ``sizes``.
    """
    axes = list(sizes)
    report = MergeReport()
    full = np.zeros(global_shape, np.float64)
    cover = np.zeros(global_shape, np.int16)
    seen: dict[tuple, tuple] = {}   # frozen slice key -> (coords, array)

    for coords_t, arr in shards.items():
        coords = dict(zip(axes, coords_t))
        frags = slices_for_rank(spec, global_shape, sizes, coords)
        key = tuple((s.start, s.stop) for f in frags for s in f)
        if key in seen:
            ref_coords, ref_arr = seen[key]
            denom = np.linalg.norm(ref_arr.astype(np.float64))
            err = np.linalg.norm(arr.astype(np.float64)
                                 - ref_arr.astype(np.float64))
            rel = err / denom if denom > 0 else err
            if rel > replica_rtol:
                report.conflicts.append(
                    {"coords": coords_t, "ref_coords": ref_coords,
                     "rel_err": float(rel)})
                report.ok = False
            continue
        seen[key] = (coords_t, arr)
        # place fragments: multi-fragment shards are concatenated along the
        # cp dim in chunk order, so walk them in the same order.
        off = 0
        cdim = (spec.cp_dim % len(global_shape)
                if (spec.cp_mode == "zigzag" and spec.cp_dim is not None)
                else None)
        for f in frags:
            if cdim is None:
                piece = arr
            else:
                ext = f[cdim].stop - f[cdim].start
                idx = [slice(None)] * arr.ndim
                idx[cdim] = slice(off, off + ext)
                piece = arr[tuple(idx)]
                off += ext
            want = tuple(s.stop - s.start for s in f)
            if piece.shape != want:
                # shard shape contradicts the annotation-derived mapping
                report.layout_mismatches.append(
                    {"coords": coords_t, "expected": want,
                     "actual": piece.shape})
                report.ok = False
                continue
            full[f] += piece.astype(np.float64)
            cover[f] += 1
    report.overlap = int(np.sum(cover > 1))
    report.omission = int(np.sum(cover == 0))
    if report.overlap or report.omission:
        report.ok = False
    return full.astype(np.float32), report


def merge_jax_array(arr, spec: ShardSpec, mesh_axes: dict[str, str],
                    replica_rtol: float = REPLICA_RTOL
                    ) -> tuple[np.ndarray, MergeReport]:
    """Rebuild + verify a sharded ``jax.Array`` against the annotation.

    ``mesh_axes`` maps parallel-axis name ("tp", "dp", ...) to the mesh axis
    name it runs on (e.g. {"dp": "data", "tp": "model"}).
    """
    mesh = arr.sharding.mesh
    sizes = {p: int(mesh.shape[m]) for p, m in mesh_axes.items()}
    report = MergeReport()
    shards = {}
    for sh in arr.addressable_shards:
        didx = {m: int(i) for m, i in zip(
            mesh.axis_names, np.argwhere(
                np.asarray(mesh.devices) == sh.device)[0])}
        coords_t = tuple(didx[mesh_axes[p]] for p in sizes)
        coords = dict(zip(sizes, coords_t))
        expected = slices_for_rank(spec, arr.shape, sizes, coords)
        actual = tuple(
            slice(s.start or 0, s.stop if s.stop is not None else dim)
            for s, dim in zip(sh.index, arr.shape))
        if len(expected) == 1 and expected[0] != actual:
            report.layout_mismatches.append(
                {"coords": coords_t, "expected": expected[0],
                 "actual": actual})
            report.ok = False
        shards[coords_t] = np.asarray(sh.data)
    full, rep2 = merge_shards(shards, spec, sizes, arr.shape, replica_rtol)
    rep2.layout_mismatches.extend(report.layout_mismatches)
    rep2.ok = rep2.ok and report.ok
    return full, rep2
