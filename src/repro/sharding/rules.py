"""GSPMD sharding rules for the production mesh (deliverable e backbone).

Maps parameter names / input kinds / cache kinds to PartitionSpecs on the
(16,16)=("data","model") single-pod or (2,16,16)=("pod","data","model")
multi-pod mesh.  Rules are written against the TRAILING dims of each leaf so
that scan-stacked parameters (leading layer dim) inherit the same rule.

GSPMD semantics guarantee sharding choices never change values — only
layout/collectives — so these rules are a performance/memory surface, which
is exactly what the roofline/perf loop (EXPERIMENTS.md §Perf) iterates on.
"""
from __future__ import annotations

import fnmatch
import re
from dataclasses import dataclass, field
from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

MODEL_AXIS = "model"


def dp_axes(mesh: Mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


# ---------------------------------------------------------------------------
# Parameter rules: (glob pattern on flattened name) -> trailing-dims spec
# ---------------------------------------------------------------------------

PARAM_RULES: list[tuple[str, tuple]] = [
    ("*embedding.word_embeddings", (MODEL_AXIS, None)),      # vocab-parallel
    ("*lm_head", (MODEL_AXIS, None)),
    ("*mask_embed", (None,)),
    ("*vision_proj.w", (None, MODEL_AXIS)),
    ("*audio_proj.w", (None, MODEL_AXIS)),
    # attention
    ("*linear_qkv.w", (None, MODEL_AXIS)),
    ("*linear_qkv.b", (MODEL_AXIS,)),
    ("*linear_proj.w", (MODEL_AXIS, None)),
    ("*q_norm", (None,)),
    ("*k_norm", (None,)),
    # MLA
    ("*linear_dq.w", (None, MODEL_AXIS)),
    ("*linear_uq.w", (None, MODEL_AXIS)),
    ("*linear_dkv.w", (None, None)),
    ("*linear_krope.w", (None, None)),
    ("*linear_uk.w", (None, MODEL_AXIS)),
    ("*linear_uv.w", (None, MODEL_AXIS)),
    # dense mlp
    ("*mlp.gate.w", (None, MODEL_AXIS)),
    ("*mlp.up.w", (None, MODEL_AXIS)),
    ("*mlp.down.w", (MODEL_AXIS, None)),
    ("*fc1.w", (None, MODEL_AXIS)),
    ("*fc1.b", (MODEL_AXIS,)),
    ("*fc2.w", (MODEL_AXIS, None)),
    # moe: expert-parallel when n_experts divides the axis, else shard the
    # ffn dim (mixtral's 8 experts < 16-way model axis)
    ("*experts.gate", [(MODEL_AXIS, None, None), (None, None, MODEL_AXIS)]),
    ("*experts.up", [(MODEL_AXIS, None, None), (None, None, MODEL_AXIS)]),
    ("*experts.down", [(MODEL_AXIS, None, None), (None, MODEL_AXIS, None)]),
    ("*mlp.router", (None, None)),
    ("*shared.gate.w", (None, MODEL_AXIS)),
    ("*shared.up.w", (None, MODEL_AXIS)),
    ("*shared.down.w", (MODEL_AXIS, None)),
    # mamba2
    ("*mixer.in_proj.w", (None, MODEL_AXIS)),
    ("*mixer.conv_w", (None, MODEL_AXIS)),
    ("*mixer.conv_b", (MODEL_AXIS,)),
    ("*mixer.out_proj.w", (MODEL_AXIS, None)),
    ("*mixer.gate_norm", (MODEL_AXIS,)),
    ("*mixer.A_log", (None,)),
    ("*mixer.D", (None,)),
    ("*mixer.dt_bias", (None,)),
    # rwkv6 time/channel mix
    ("*time_mix.recept.w", (None, MODEL_AXIS)),
    ("*time_mix.key.w", (None, MODEL_AXIS)),
    ("*time_mix.value.w", (None, MODEL_AXIS)),
    ("*time_mix.gate.w", (None, MODEL_AXIS)),
    ("*time_mix.out.w", (MODEL_AXIS, None)),
    ("*time_mix.decay_B", (None, MODEL_AXIS)),
    ("*time_mix.w0", (MODEL_AXIS,)),
    ("*time_mix.ln_out", (MODEL_AXIS,)),
    ("*time_mix.u", (MODEL_AXIS, None)),
    ("*channel_mix.key.w", (None, MODEL_AXIS)),
    ("*channel_mix.value.w", (MODEL_AXIS, None)),
    ("*channel_mix.recept.w", (None, MODEL_AXIS)),
]


def param_pspec(name: str, shape: tuple, mesh: Mesh) -> P:
    """Resolve the rule for a flattened param name; leading (scan) dims get
    None.  A rule may give ALTERNATIVE specs (first whose sharded dims all
    divide wins); dims that don't divide fall back to replication."""
    cands: list[tuple] = [()]
    for pat, s in PARAM_RULES:
        if fnmatch.fnmatchcase(name, pat):
            cands = s if isinstance(s, list) else [s]
            break
    ndim = len(shape)

    def resolve(spec, strict):
        full = ([None] * (ndim - len(spec)) + list(spec))[:ndim]
        out = []
        for dim, ax in zip(shape, full):
            if ax is not None and dim % mesh.shape[ax] == 0:
                out.append(ax)
            elif ax is not None and strict:
                return None
            else:
                out.append(None)
        return P(*out)

    for spec in cands:
        r = resolve(spec, strict=True)
        if r is not None:
            return r
    return resolve(cands[0], strict=False)


def with_data_axis(spec: P, shape: tuple, mesh: Mesh,
                   axes: tuple = ("data",)) -> P:
    """ZeRO-style densification: additionally shard the first dim that is
    unsharded and divisible — used for fp32 optimizer state."""
    size = int(np.prod([mesh.shape[a] for a in axes]))
    entries = list(spec) + [None] * (len(shape) - len(spec))
    for i, (dim, ax) in enumerate(zip(shape, entries)):
        if ax is None and dim % size == 0:
            entries[i] = axes if len(axes) > 1 else axes[0]
            return P(*entries)
    return spec


def param_shardings(named_shapes: dict, mesh: Mesh, opt_state: bool = False
                    ) -> dict:
    out = {}
    for name, shp in named_shapes.items():
        spec = param_pspec(name, shp, mesh)
        if opt_state:
            spec = with_data_axis(spec, shp, mesh, dp_axes(mesh))
        out[name] = NamedSharding(mesh, spec)
    return out


# ---------------------------------------------------------------------------
# Batch / cache specs
# ---------------------------------------------------------------------------

def batch_pspec(mesh: Mesh, batch_size: int) -> P:
    """Shard the global batch over (pod, data) — dropping axes that don't
    divide (long_500k has batch 1)."""
    axes = [a for a in dp_axes(mesh)]
    keep = []
    rem = batch_size
    for a in axes:
        if rem % mesh.shape[a] == 0 and mesh.shape[a] > 1:
            keep.append(a)
            rem //= mesh.shape[a]
    if not keep:
        return P(None)
    return P(tuple(keep) if len(keep) > 1 else keep[0])


def seq_axes_for(mesh: Mesh, batch_sharded: bool) -> Optional[tuple]:
    """When the batch can't be sharded (long-context decode), context-
    parallel the sequence/cache dim over the dp axes instead."""
    return None if batch_sharded else dp_axes(mesh)


def cache_pspec(path: str, shape: tuple, mesh: Mesh, batch_sharded: bool,
                batch_dim: int) -> P:
    """Generic KV/state cache rule: batch dim over (pod,data) when it
    divides, else the longest dim (the sequence) context-parallel over the
    dp axes; one heads/feature dim over "model" where divisible."""
    entries: list = [None] * len(shape)
    dp = dp_axes(mesh)
    dp_size = int(np.prod([mesh.shape[a] for a in dp]))
    if batch_sharded and shape[batch_dim] % dp_size == 0:
        entries[batch_dim] = dp if len(dp) > 1 else dp[0]
    else:
        # context-parallel: shard the largest (sequence) dim
        seq_dim = int(np.argmax(shape))
        if shape[seq_dim] % dp_size == 0 and seq_dim != batch_dim:
            entries[seq_dim] = dp if len(dp) > 1 else dp[0]
    # one more dim over model, preferring trailing head-ish dims
    msize = mesh.shape[MODEL_AXIS]
    for i in range(len(shape) - 2, -1, -1):
        if entries[i] is None and i != batch_dim and shape[i] % msize == 0 \
                and shape[i] >= msize:
            entries[i] = MODEL_AXIS
            break
    return P(*entries)


# ---------------------------------------------------------------------------
# In-model sharding constraints (activation layout hints)
# ---------------------------------------------------------------------------

@dataclass
class ShardingCtx:
    mesh: Mesh
    batch_sharded: bool = True

    def _wsc(self, x, spec):
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, spec))

    def btd(self, x):
        """Residual-stream activations (B, S, d)."""
        dp = dp_axes(self.mesh)
        dpa = dp if len(dp) > 1 else dp[0]
        if self.batch_sharded:
            return self._wsc(x, P(dpa, None, None))
        return self._wsc(x, P(None, dpa, None))       # context-parallel seq

    def moe_buf(self, x):
        """Expert dispatch buffer (E, C, d): experts over model, capacity
        over the dp axes."""
        dp = dp_axes(self.mesh)
        dpa = dp if len(dp) > 1 else dp[0]
        E, C = x.shape[0], x.shape[1]
        e_ax = MODEL_AXIS if E % self.mesh.shape[MODEL_AXIS] == 0 else None
        dsz = int(np.prod([self.mesh.shape[a] for a in dp]))
        c_ax = dpa if C % dsz == 0 else None
        return self._wsc(x, P(e_ax, c_ax, None))

    def grouped(self, x):
        """(G, ...) per-data-shard grouped tensors: G over the dp axes."""
        dp = dp_axes(self.mesh)
        dpa = dp if len(dp) > 1 else dp[0]
        dsz = int(np.prod([self.mesh.shape[a] for a in dp]))
        if x.shape[0] % dsz != 0:
            return x
        return self._wsc(x, P(*([dpa] + [None] * (x.ndim - 1))))

    def vmapped_buf(self, x):
        """(E, C, d) buffer inside a vmapped dispatch: constrain only the
        expert/ffn dims (the hidden group batch dim is handled by GSPMD
        propagation from the grouped inputs)."""
        e_ax = (MODEL_AXIS if x.shape[-3] % self.mesh.shape[MODEL_AXIS] == 0
                else None)
        if x.ndim == 3:
            return self._wsc(x, P(e_ax, None, None))
        return self._wsc(x, P(None, e_ax, None, None))

    def grouped_buf(self, x):
        """(G, E, C, d) grouped dispatch buffers: G over dp, E over model
        when divisible."""
        dp = dp_axes(self.mesh)
        dpa = dp if len(dp) > 1 else dp[0]
        dsz = int(np.prod([self.mesh.shape[a] for a in dp]))
        g_ax = dpa if x.shape[0] % dsz == 0 else None
        e_ax = (MODEL_AXIS if x.shape[1] % self.mesh.shape[MODEL_AXIS] == 0
                else None)
        return self._wsc(x, P(g_ax, e_ax, None, None))

    def flat_tokens(self, x):
        """(T[*k], d) flattened token tensors in the MoE dispatch/combine:
        shard the token dim over the dp axes (GSPMD cannot infer sharding
        through the sort/gather, and left alone it replicates ~T*k*d fp32
        — the deepseek prefill memory cliff)."""
        dp = dp_axes(self.mesh)
        dpa = dp if len(dp) > 1 else dp[0]
        dsz = int(np.prod([self.mesh.shape[a] for a in dp]))
        if x.shape[0] % dsz != 0:
            return x
        return self._wsc(x, P(*([dpa] + [None] * (x.ndim - 1))))


_CTX: list = []


def push_ctx(ctx: ShardingCtx):
    _CTX.append(ctx)


def pop_ctx():
    _CTX.pop()


def current() -> Optional[ShardingCtx]:
    return _CTX[-1] if _CTX else None


def constrain(x, kind: str):
    ctx = current()
    if ctx is None:
        return x
    return getattr(ctx, kind)(x)


def dispatch_groups(n_tokens: int, n_experts: int = 0) -> int:
    """Number of MoE dispatch groups: one per data shard when a sharding
    context is active (and the token count divides), else 1.

    Grouping only pays when the experts are truly expert-parallel
    (n_experts divisible by the model axis); otherwise (e.g. mixtral's 8
    experts on a 16-way axis) the vmapped buffers add resharding without
    the EP win — measured +56 GiB on mixtral train (EXPERIMENTS.md §Perf)."""
    ctx = current()
    if ctx is None:
        return 1
    if n_experts and n_experts % ctx.mesh.shape[MODEL_AXIS] != 0:
        return 1
    dp = dp_axes(ctx.mesh)
    dsz = int(np.prod([ctx.mesh.shape[a] for a in dp]))
    return dsz if n_tokens % dsz == 0 and ctx.batch_sharded else 1


class activate:
    """``with rules.activate(mesh, batch_sharded):`` — enables the in-model
    with_sharding_constraint hooks for a lowering."""

    def __init__(self, mesh: Mesh, batch_sharded: bool = True):
        self.ctx = ShardingCtx(mesh, batch_sharded)

    def __enter__(self):
        push_ctx(self.ctx)
        return self.ctx

    def __exit__(self, *a):
        pop_ctx()
