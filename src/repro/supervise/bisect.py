"""First-bad-step bisection over supervisor checkpoints.

Online detection can lag the actual divergence: checks may be subsampled
(``check_every > 1``), resolve late (async window), or a slow update-path
drift may cross the threshold only steps after the buggy update started
(stale ZeRO gathers, drifting tied embeddings).  When a flag lands, the
supervisor wants the FIRST step at which the candidate left the reference
beyond FP explanation — that is where the buggy code ran.

Two-phase search, O(log C) cheap probes + one bounded replay:

1. **Checkpoint binary search.**  The supervisor saves both sides' full
   (params, opt_state) every ``ckpt_every`` steps (bit-exact sharded-npz
   round trip).  Comparing the two sides' *parameters* at a checkpoint is a
   cheap divergence probe — no training, one batched reduction — so binary
   search over checkpoints brackets the divergence to one checkpoint
   interval and, crucially, finds the latest provably-good restore point.
2. **Sync replay.**  Restore both sides at that checkpoint and re-run the
   lockstep loop with synchronous per-step checking until a step flags.
   Replay is deterministic (stateless data generator + bit-exact restore +
   the same compiled steps), so the first flagged replay step IS the first
   bad step of the original run.  Both the divergence probe and the replay
   checks evaluate each step against the pipeline's threshold schedule for
   THAT step (``AsyncCheckPipeline.thresholds_for`` — with periodic
   re-estimation, the epoch the step originally trained under), so the
   replay verdicts reproduce the online ones.

The probe and replay are recipe-agnostic: they only assume the candidate's
persistent state is a ``(params, opt_state)`` pytree with reference-named
param leaves — true for the shard_map, pipeline-parallel and FP8
``CandidateStep`` implementations alike.

The resulting step report is then handed to the existing localization
machinery (propagation/backward/optimizer modes, and rewrite-mode
isolation when the divergence is in the forward pass).
"""
from __future__ import annotations

import os
import shutil
import threading
from dataclasses import dataclass, field
from typing import Callable, Optional

from repro.checkpoint.store import (MANIFEST, ChecksumError, load_checkpoint,
                                    load_checkpoint_named, save_checkpoint)
from repro.supervise.pipeline import StepCheck
from repro.supervise.store import BackgroundWriter


class CheckpointKeeper:
    """Periodic dual-side (reference, candidate) training-state checkpoints.

    ``step`` indexes the state BEFORE that step runs: the step-0 checkpoint
    is the initial state, the step-k checkpoint is after steps 0..k-1.

    Disk use is bounded like the trace ring: when more than ``keep``
    checkpoints accumulate, retention thins to log-spaced steps (doubling
    stride, always keeping step 0 and the newest), which preserves the
    binary-search probe's O(log) bracketing at coarser granularity instead
    of growing linearly with run length.

    ``background=True`` routes the serialization through a bounded-queue
    ``BackgroundWriter`` (same machinery as the trace ring's spill path):
    ``save`` enqueues immutable state references and returns, training
    dispatches ahead while the writer drains.  Every read path —
    ``load``, ``load_params_named``, ``verify`` — flushes the queue first,
    so bisection never restores a checkpoint that is still in flight.
    A writer failure surfaces on the next ``save()`` (and at ``flush()``),
    after which the worker restarts.
    """

    def __init__(self, root: str, keep: int = 16, background: bool = False,
                 queue_max: int = 2):
        self.root = root
        self.keep = keep
        self._stride = 1
        os.makedirs(root, exist_ok=True)
        self.steps: list[int] = []
        self._lock = threading.Lock()
        self._writer = (BackgroundWriter("ckpt-writer", queue_max=queue_max)
                        if background else None)
        #: fires after a checkpoint write lands (supervisor journals it;
        #: the fault harness corrupts payloads here)
        self.on_save: Optional[Callable[[int, str], None]] = None

    def _dir(self, step: int) -> str:
        return os.path.join(self.root, f"step_{step:06d}")

    def save(self, step: int, ref_state, cand_state) -> None:
        """``*_state`` are ``(params, opt_state)`` pytrees.  jax arrays are
        immutable, so enqueueing references is snapshot-safe — the training
        loop rebinds new states, it never mutates these."""
        if self._writer is not None:
            err = self._writer.take_error()
            if err is not None:
                raise err
            self._writer.submit(
                lambda: self._write(step, ref_state, cand_state))
        else:
            self._write(step, ref_state, cand_state)

    def _write(self, step: int, ref_state, cand_state) -> None:
        save_checkpoint(self._dir(step),
                        {"ref": {"params": ref_state[0], "opt": ref_state[1]},
                         "cand": {"params": cand_state[0],
                                  "opt": cand_state[1]}},
                        step=step)
        with self._lock:
            if step not in self.steps:
                self.steps.append(step)
                self.steps.sort()
        self._prune()
        if self.on_save is not None:
            self.on_save(step, self._dir(step))

    def flush(self) -> None:
        """Block until every queued save landed; re-raise a writer error.
        Called before every restore and before any bisection."""
        if self._writer is not None:
            self._writer.flush()

    def stop(self) -> None:
        """End the save worker thread (drains first; restarts on the next
        ``save``) — end-of-run teardown, not a terminal state."""
        if self._writer is not None:
            self._writer.stop()

    def verify(self, step: int) -> bool:
        """Full CRC verification of a checkpoint (host read of every
        piece).  The resume path uses this to trust only checkpoints that
        survived the crash intact."""
        self.flush()
        try:
            load_checkpoint_named(self._dir(step))
            return True
        except (ChecksumError, FileNotFoundError):
            return False

    def rescan(self) -> list[int]:
        """Rebuild the step index from disk (the resume path: a previous
        incarnation's checkpoints become addressable again)."""
        found = []
        if os.path.isdir(self.root):
            for d in sorted(os.listdir(self.root)):
                if d.startswith("step_") and os.path.exists(
                        os.path.join(self.root, d, MANIFEST)):
                    found.append(int(d[len("step_"):]))
        with self._lock:
            self.steps = sorted(set(self.steps) | set(found))
        return found

    def discard(self, step: int) -> None:
        """Drop a checkpoint that failed verification (corrupt payload) so
        bisection and resume stop considering it."""
        with self._lock:
            if step in self.steps:
                self.steps.remove(step)
        shutil.rmtree(self._dir(step), ignore_errors=True)

    def _prune(self) -> None:
        if not self.keep:
            return
        doomed = []
        with self._lock:
            while len(self.steps) > self.keep:
                self._stride *= 2
                newest = self.steps[-1]
                removed = False
                for s in list(self.steps):
                    if s in (0, newest) or s % self._stride == 0:
                        continue
                    doomed.append(self._dir(s))
                    self.steps.remove(s)
                    removed = True
                if not removed:
                    break          # only {0, newest} left (keep < 2)
        for d in doomed:
            shutil.rmtree(d, ignore_errors=True)

    def load_params_named(self, step: int):
        """Host-only restore of just the two PARAM trees as flat
        ``{name: numpy}`` dicts — the cheap divergence probe's payload (no
        optimizer state, no device placement)."""
        self.flush()
        named, _, _ = load_checkpoint_named(self._dir(step))
        ref = {k[len("ref.params."):]: v for k, v in named.items()
               if k.startswith("ref.params.")}
        cand = {k[len("cand.params."):]: v for k, v in named.items()
                if k.startswith("cand.params.")}
        return ref, cand

    def load(self, step: int, ref_template, cand_template):
        """Returns ``((ref_params, ref_opt), (cand_params, cand_opt))``,
        placed like the template trees (bit-exact values)."""
        self.flush()
        template = {"ref": {"params": ref_template[0],
                            "opt": ref_template[1]},
                    "cand": {"params": cand_template[0],
                             "opt": cand_template[1]}}
        tree, _, _ = load_checkpoint(self._dir(step), template)
        return ((tree["ref"]["params"], tree["ref"]["opt"]),
                (tree["cand"]["params"], tree["cand"]["opt"]))


@dataclass
class BisectResult:
    first_bad_step: int
    check: StepCheck              # the sync replay report at that step
    replay_from: int              # latest provably-good checkpoint
    probes: list = field(default_factory=list)   # [(ckpt_step, diverged)]
    replayed_steps: int = 0

    def summary(self) -> str:
        probes = ", ".join(f"{s}:{'BAD' if d else 'ok'}"
                           for s, d in self.probes) or "none"
        return (f"bisection: first bad step {self.first_bad_step} "
                f"(replayed {self.replayed_steps} steps from checkpoint "
                f"{self.replay_from}; checkpoint probes: {probes})")


def bisect_first_bad(ckpt_steps, flagged_step: int,
                     diverged: Callable[[int], bool],
                     replay: Callable[[int, int], Optional[StepCheck]]
                     ) -> BisectResult:
    """Find the first bad step given a flag at ``flagged_step``.

    ``diverged(ckpt_step)`` — cheap parameter-divergence probe at a
    checkpoint.  ``replay(start, end)`` — restore at ``start`` and re-run
    with sync checks, returning the first flagged StepCheck (or None if
    nothing flags up to ``end`` — the caller's online flag then stands).
    """
    cands = sorted(s for s in ckpt_steps if 0 < s <= flagged_step)
    good, probes = 0, []
    lo, hi = 0, len(cands) - 1
    while lo <= hi:
        mid = (lo + hi) // 2
        d = bool(diverged(cands[mid]))
        probes.append((cands[mid], d))
        if d:
            hi = mid - 1
        else:
            good = cands[mid]
            lo = mid + 1
    check = replay(good, flagged_step)
    if check is None:
        # replay found nothing below threshold-schedule — keep the online
        # flag as the answer (conservative; should not happen with a
        # deterministic replay)
        return BisectResult(flagged_step, StepCheck(flagged_step, None),
                            good, probes, flagged_step - good + 1)
    return BisectResult(check.step, check, good, probes,
                        check.step - good + 1)
