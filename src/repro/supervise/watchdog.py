"""Watchdog + graceful degradation for the supervised hot loop.

TTrace hunts *silent* bugs, but the fleets supervision must live in fail
*loudly* and often (FLARE, Mycroft — PAPERS.md): device futures hang,
collectives stall, disks corrupt.  A supervisor that stalls or dies with
its subject is useless, so every host-blocking wait in the loop goes
through a ``Watchdog`` with a retry-then-fallback escalation ladder:

1. **wait** for the result with a timeout (the transfer runs on a watchdog
   worker thread so the supervisor's own thread can give up on it);
2. on timeout, **retry** once (transient scheduler stalls resolve
   themselves; the abandoned worker thread is left to the hung transfer
   and a fresh one takes over);
3. still stuck: **escalate** — the async check falls back to a synchronous
   recompute from the trace ring (``CheckTimeout``), a stage-boundary
   transfer raises ``BoundaryTimeout`` and the step is reported as a LOUD
   failure instead of freezing the run.

``DegradationController`` is the backpressure policy above the ladder:
when the pipeline saturates (in-flight window full with an unresolvable
oldest entry) for ``degrade_after`` consecutive checked steps, checking
degrades to *sampling* — the effective ``check_every`` doubles — so
training keeps progressing while checks are sick, instead of paying a
timeout per step.  Sustained health recovers one rung at a time.  Every
transition is an event (journaled by the supervisor and surfaced in the
result summary): degraded coverage is visible, never silent.

Loud failures themselves (NaN/Inf in the candidate) are classified by the
checker (``report_from_errs`` marks non-finite rel-errs ``LOUD``) — before
that fix a NaN rel-err compared ``False`` against every threshold and
*passed*; the classic way a loud failure drowns in rel-err machinery.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Callable, Optional


class LoudFault(RuntimeError):
    """A non-silent failure: hang, corruption, NaN — reported, not hidden."""


class CheckTimeout(LoudFault):
    """An async check's device future never resolved within the ladder."""


class BoundaryTimeout(LoudFault):
    """A stage-boundary transfer future never became ready."""


@dataclass
class WatchdogEvent:
    step: int
    kind: str        # timeout | retry | sync_fallback | check_lost |
    #                # degrade | recover | loud
    detail: str = ""

    def __str__(self) -> str:
        return f"step {self.step}: {self.kind}" + (
            f" ({self.detail})" if self.detail else "")


class Watchdog:
    """Timeout/retry/escalate wrapper around host-blocking waits.

    ``wait(fn, what, step)`` runs ``fn`` on a single persistent worker
    thread and joins it with ``timeout_s``; on timeout it retries
    ``retries`` times (same call, fresh timeout) and then raises
    ``CheckTimeout``.  A worker stuck on a hung wait is abandoned (daemon
    thread) and replaced, so one poisoned future cannot wedge every later
    wait.  ``on_event`` (set by the supervisor) journals every escalation.
    """

    def __init__(self, timeout_s: float = 60.0, retries: int = 1,
                 on_event: Optional[Callable[[WatchdogEvent], None]] = None):
        self.timeout_s = float(timeout_s)
        self.retries = max(0, int(retries))
        self.on_event = on_event
        self.events: list[WatchdogEvent] = []
        self.timeouts = 0

    def event(self, kind: str, step: int, detail: str = "") -> WatchdogEvent:
        ev = WatchdogEvent(step, kind, detail)
        self.events.append(ev)
        if self.on_event is not None:
            self.on_event(ev)
        return ev

    def events_since(self, n: int) -> list[WatchdogEvent]:
        return self.events[n:]

    def _attempt(self, fn: Callable, timeout_s: float):
        box: dict = {}

        def target():
            try:
                box["value"] = fn()
            except BaseException as e:     # noqa: BLE001 — re-raised below
                box["error"] = e

        t = threading.Thread(target=target, daemon=True,
                             name="watchdog-wait")
        t.start()
        t.join(timeout_s)
        if t.is_alive():
            return False, None             # abandoned: daemon thread leaks
        if "error" in box:
            raise box["error"]
        return True, box.get("value")

    def wait(self, fn: Callable, what: str, step: int):
        """Run ``fn`` under the timeout ladder; raises ``CheckTimeout``
        after the final retry expires."""
        for attempt in range(self.retries + 1):
            ok, value = self._attempt(fn, self.timeout_s)
            if ok:
                return value
            self.timeouts += 1
            kind = "retry" if attempt < self.retries else "timeout"
            self.event(kind, step,
                       f"{what} exceeded {self.timeout_s:g}s "
                       f"(attempt {attempt + 1})")
        raise CheckTimeout(f"{what} at step {step} still unresolved after "
                           f"{self.retries + 1} x {self.timeout_s:g}s")


def wait_ready(value, deadline_s: Optional[float], what: str,
               poll_s: float = 0.001):
    """Block until a device future reports ready, with a deadline.

    Used by ``BoundaryTransport`` on recv: a transfer whose producer died
    turns into a ``BoundaryTimeout`` (a loud, localized failure) instead of
    an infinite stall inside the schedule.  Values without an ``is_ready``
    probe (numpy, older jax) pass straight through — the subsequent use
    blocks natively, exactly as before."""
    if deadline_s is None:
        return value
    probe = getattr(value, "is_ready", None)
    if probe is None:
        return value
    t0 = time.monotonic()
    wait = poll_s
    while not probe():
        if time.monotonic() - t0 > deadline_s:
            raise BoundaryTimeout(f"{what} not ready after {deadline_s:g}s")
        time.sleep(wait)
        wait = min(wait * 2, 0.05)
    return value


@dataclass
class DegradationController:
    """Sampling-degradation policy: trade check *coverage* for progress.

    ``note(step, stalled)`` is called once per would-be-checked step.
    ``degrade_after`` consecutive stalled steps double the effective
    ``check_every`` (up to ``max_mult`` x the base); the same count of
    consecutive healthy checked steps recovers one halving.  Transitions
    emit events through ``on_event``.
    """
    check_every: int
    degrade_after: int = 3
    max_mult: int = 8
    on_event: Optional[Callable[[WatchdogEvent], None]] = None
    mult: int = 1
    _stalled: int = 0
    _healthy: int = 0
    events: list = field(default_factory=list)

    @property
    def effective_check_every(self) -> int:
        return self.check_every * self.mult

    @property
    def degraded(self) -> bool:
        return self.mult > 1

    def _emit(self, kind: str, step: int) -> None:
        ev = WatchdogEvent(step, kind,
                           f"effective check_every -> "
                           f"{self.effective_check_every}")
        self.events.append(ev)
        if self.on_event is not None:
            self.on_event(ev)

    def note(self, step: int, stalled: bool) -> None:
        if stalled:
            self._stalled += 1
            self._healthy = 0
            if (self._stalled >= self.degrade_after
                    and self.mult < self.max_mult):
                self.mult *= 2
                self._stalled = 0
                self._emit("degrade", step)
        else:
            self._healthy += 1
            self._stalled = 0
            if self._healthy >= self.degrade_after and self.mult > 1:
                self.mult //= 2
                self._healthy = 0
                self._emit("recover", step)
