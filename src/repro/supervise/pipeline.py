"""Double-buffered async checking for the streaming supervisor.

Synchronous per-step checking (``compare_traces`` on the training loop)
serializes: dispatch the reduction, BLOCK for the ``(N, 2)`` scalars, build
the report, only then dispatch step k+1 — host and device take turns idling.
This pipeline splits the check into the two passes the checker already
exposes:

* at ``submit(k)`` the metadata pass runs (no transfer) and the whole-trace
  pair reduction is dispatched on device (``relerr_engine.sq_norms_async``) —
  the returned ``jax.Array`` is held as a future;
* resolution (host transfer of N x 2 scalars + threshold comparison +
  localization) happens when the entry leaves the bounded in-flight window,
  by which time step k+1's compute has been dispatched behind it.

The window is the backpressure bound: at most ``window`` step reductions
(and the trace leaves they reference) are in flight; submitting beyond it
resolves the oldest entry first, so device memory for pending checks stays
O(window), never O(run length).

Thresholds are estimated at step 0 (paper §5) and — when the supervisor's
periodic re-estimation is on — refreshed every R steps from the live batch
and swapped in as a new *threshold epoch* (``swap_thresholds``).  Each
check resolves against the epoch active at its OWN step, so late async
resolutions and bisection replays see the schedule the step trained under.
Multi-step checking needs two allowances on top of the estimates:

* per-step kinds (activations / gradients) see batch-to-batch variation of
  the true FP-noise level that a single-batch estimate misses — measured at
  up to ~8x on clean runs — so they get a constant widening
  (``SUPERVISED_KIND_MULT``, bug errors sit ~100-1000x above thresholds).
  With re-estimation the estimates track the live noise level (and only
  ever widen, ``Thresholds.union``), so the widening tightens to
  ``REESTIMATED_KIND_MULT`` — back toward the paper's single-step 8x;
* both sides accumulate independent round-off as states evolve, so every
  threshold additionally grows by ``1 + drift_alpha * step`` (anchored at
  step 0: accumulated ref/cand divergence never resets, re-estimation or
  not).

``param_post_step`` keeps multiplier 1.0: the post-step parameter comparison
is cumulative state, empirically flat on clean runs (~0.1x threshold), and
it is exactly the signal that catches slow update-path drift — widening it
would blind the supervisor to the bugs it exists for.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass
from typing import Any, Callable, Optional

import numpy as np

from repro.core import canonical as C
from repro.core.checker import (DEFAULT_KINDS, Report, collect_section_pairs,
                                merge_problems_of, report_from_errs)
from repro.core.relerr_engine import _to_rel_err, sq_norms_async
from repro.core.thresholds import Thresholds
from repro.supervise.watchdog import CheckTimeout, Watchdog

SUPERVISED_KIND_MULT = {
    C.KIND_ACT: 8.0,
    C.KIND_ACT_GRAD: 8.0,
    C.KIND_PARAM_GRAD: 16.0,
    C.KIND_MAIN_GRAD: 16.0,
    C.KIND_PARAM_POST: 1.0,
}

# margins under periodic re-estimation: the live union-of-estimates absorbs
# most batch-to-batch variation, so the constant widening tightens (4-8x vs
# 8-16x) back toward the paper's single-step margin
REESTIMATED_KIND_MULT = {
    C.KIND_ACT: 4.0,
    C.KIND_ACT_GRAD: 4.0,
    C.KIND_PARAM_GRAD: 8.0,
    C.KIND_MAIN_GRAD: 8.0,
    C.KIND_PARAM_POST: 1.0,
}


@dataclass
class StepCheck:
    """One resolved online check: the step index and its report."""
    step: int
    report: Report

    @property
    def flagged(self) -> bool:
        return not self.report.passed


class AsyncCheckPipeline:
    """Bounded-window async differential checking over a supervised run."""

    def __init__(self, thresholds: Thresholds, window: int = 2,
                 kinds=DEFAULT_KINDS, kind_mult=None,
                 drift_alpha: float = 0.125, kind_scale: float = 1.0):
        self.window = max(0, int(window))
        self.kinds = kinds
        self.drift_alpha = drift_alpha
        # recipe-supplied widening of the per-step kind margins: candidates
        # whose numerics legitimately reassociate more than the reference
        # (1F1B microbatch grad accumulation sums M partial reductions)
        # declare their allowance here.  param_post_step is exempt — it is
        # the slow-drift signal and stays at multiplier 1.0.
        self.kind_scale = float(kind_scale)
        # threshold epochs: (from_step, thresholds, kind_mult), sorted; a
        # step's check uses the last epoch with from_step <= step
        self._epochs: list[tuple[int, Thresholds, dict]] = [
            (0, thresholds, dict(SUPERVISED_KIND_MULT if kind_mult is None
                                 else kind_mult))]
        # pending epochs whose estimate is still a device future:
        # (from_step, resolve() -> Thresholds, kind_mult), settled lazily —
        # a check of step >= from_step forces resolution first, so results
        # are bit-identical to resolving at submission
        self._pending_epochs: list[tuple[int, Any, dict]] = []
        self.epochs_settled = 0
        self._inflight: deque = deque()
        self._clock = 0            # monotone submit/poll tick counter
        self.submitted = 0
        self.resolved = 0
        self.max_in_flight = 0
        # fault-tolerance hooks, all wired by the supervisor:
        #: watchdog ladder around the resolution transfer (None = block)
        self.watchdog: Optional[Watchdog] = None
        #: sync recompute of a timed-out check from retained traces;
        #: raises KeyError when the evidence is gone
        self.fallback: Optional[Callable[[int], "StepCheck"]] = None
        #: journal callback for every settled threshold epoch
        self.on_epoch: Optional[Callable[[int, Thresholds, dict],
                                         None]] = None
        #: fault-injection tap on the submitted device future
        self.tap_future: Optional[Callable[[int, Any], Any]] = None
        self.rescued = 0
        self.lost = 0

    # ---- threshold schedule ------------------------------------------------
    @property
    def thresholds(self) -> Thresholds:
        return self._epochs[-1][1]

    @property
    def kind_mult(self) -> dict:
        return self._epochs[-1][2]

    def swap_thresholds(self, thr: Thresholds, step: int,
                        kind_mult=None) -> None:
        """Install re-estimated thresholds for checks at steps >= ``step``.

        In-flight entries from earlier steps keep resolving against their
        own epoch, and bisection replays of earlier steps see the schedule
        those steps originally trained under."""
        km = dict(self.kind_mult if kind_mult is None else kind_mult)
        self._epochs.append((step, thr, km))
        self._epochs.sort(key=lambda e: e[0])

    def schedule_epoch(self, step: int, resolve, kind_mult=None) -> None:
        """Register a threshold epoch whose estimate is still in flight.

        ``resolve() -> Thresholds`` is the estimate's resolution (host
        transfer of the reduction scalars).  The epoch is settled — resolved,
        union-merged onto the running thresholds, installed for checks at
        steps >= ``step`` — lazily: either when a check at such a step needs
        it (determinism: the check sees exactly the epoch it would have seen
        under synchronous estimation) or at ``drain()``.  Until then the
        estimate overlaps training compute instead of stalling the loop."""
        km = dict(self.kind_mult if kind_mult is None else kind_mult)
        self._pending_epochs.append((int(step), resolve, km))
        self._pending_epochs.sort(key=lambda e: e[0])

    def settle_epochs(self, step=None) -> int:
        """Resolve pending epochs with ``from_step <= step`` (all of them
        when ``step`` is None), in submission order."""
        n = 0
        while self._pending_epochs and (
                step is None or self._pending_epochs[0][0] <= step):
            s, resolve, km = self._pending_epochs.pop(0)
            merged = self.thresholds.union(resolve())
            self._epochs.append((s, merged, km))
            self._epochs.sort(key=lambda e: e[0])
            self.epochs_settled += 1
            if self.on_epoch is not None:
                # a settled epoch is a durable fact: a resume must replay
                # it (a pending estimate dies with the process and only
                # re-running its step reproduces it)
                self.on_epoch(s, merged, km)
            n += 1
        return n

    def _epoch_for(self, step: int) -> tuple[int, Thresholds, dict]:
        self.settle_epochs(step)
        ep = self._epochs[0]
        for e in self._epochs:
            if e[0] <= step:
                ep = e
            else:
                break
        return ep

    def thresholds_for(self, step: int) -> Thresholds:
        return self._epoch_for(step)[1]

    def scales(self, step: int) -> dict:
        """Per-kind threshold scale at ``step``.  Step 0 compares identical
        states on the estimation batch — exact single-step semantics, except
        the recipe's ``kind_scale``: a candidate's own reassociation (1F1B
        microbatch accumulation) is present from the very first step."""
        def recipe(k):
            return self.kind_scale if k != C.KIND_PARAM_POST else 1.0
        if step == 0:
            return {k: recipe(k) for k in self.kinds}
        mult = self._epoch_for(step)[2]
        growth = 1.0 + self.drift_alpha * step
        return {k: mult.get(k, 1.0) * growth * recipe(k)
                for k in self.kinds}

    def param_post_threshold(self, name: str, step: int) -> float:
        """Post-step parameter threshold at ``step`` — the bisection
        probe's schedule (shared with the online checks)."""
        thr = self.thresholds_for(step)
        scale = self.scales(step).get(C.KIND_PARAM_POST, 1.0)
        return thr.threshold(C.KIND_PARAM_POST, name) * scale

    # ---- pipeline ----------------------------------------------------------
    @property
    def in_flight(self) -> int:
        return len(self._inflight)

    @property
    def saturated(self) -> bool:
        """True when the in-flight window is full AND its oldest entry is
        not ready — the next submit will BLOCK on a slow/hung resolution.
        The degradation controller's stall signal."""
        if self.window == 0 or len(self._inflight) < self.window:
            return False
        ready = getattr(self._inflight[0][4], "is_ready", None)
        return ready is not None and not ready()

    def submit(self, step: int, ref, cand) -> list[StepCheck]:
        """Enqueue the step-``step`` check; returns any checks that the
        backpressure bound forced to resolve (oldest first)."""
        entries, la, lb, missing = collect_section_pairs(ref, cand,
                                                         self.kinds)
        dev = sq_norms_async(la, lb)
        if self.tap_future is not None:
            dev = self.tap_future(step, dev)
        self._clock += 1
        self._inflight.append((step, entries, missing,
                               merge_problems_of(cand), dev, self._clock))
        self.submitted += 1
        done = []
        while len(self._inflight) > self.window:
            done.append(self._resolve())
        self.max_in_flight = max(self.max_in_flight, len(self._inflight))
        return done

    def poll(self) -> list[StepCheck]:
        """Resolve entries whose device reduction already finished — free
        progress on steps where nothing was submitted.  When the device
        array exposes no ``is_ready`` (older jax), fall back to resolving
        entries older than the window in pipeline ticks, so the pipeline
        still drains instead of deferring everything to ``drain()``."""
        self._clock += 1
        # settle pending threshold epochs whose device reduction already
        # finished (in order — an unready head blocks later epochs so the
        # union sequence stays the synchronous one)
        while self._pending_epochs and getattr(
                self._pending_epochs[0][1], "ready", lambda: False)():
            self.settle_epochs(self._pending_epochs[0][0])
        done = []
        while self._inflight:
            dev, born = self._inflight[0][4], self._inflight[0][5]
            ready = getattr(dev, "is_ready", None)
            if ready is not None:
                if not ready():
                    break
            elif self._clock - born <= self.window:
                break              # age fallback: not old enough yet
            done.append(self._resolve())
        return done

    def drain(self) -> list[StepCheck]:
        """Resolve everything still in flight (end of run), pending
        threshold epochs included."""
        done = []
        while self._inflight:
            done.append(self._resolve())
        self.settle_epochs()
        return done

    def check_sync(self, step: int, ref, cand) -> StepCheck:
        """Synchronous one-step check with the supervised threshold schedule
        (the bisection replay path, and the ``--async-window 0`` mode)."""
        entries, la, lb, missing = collect_section_pairs(ref, cand,
                                                         self.kinds)
        errs = _to_rel_err(np.asarray(sq_norms_async(la, lb), np.float64))
        rep = report_from_errs(entries, errs, self.thresholds_for(step),
                               missing=missing, thr_scale=self.scales(step),
                               merge_problems=merge_problems_of(cand))
        return StepCheck(step, rep)

    def _resolve(self) -> StepCheck:
        step, entries, missing, merge_problems, dev, _ = \
            self._inflight.popleft()
        try:
            if self.watchdog is not None:
                arr = self.watchdog.wait(
                    lambda: np.asarray(dev, np.float64),
                    "check transfer", step)
            else:
                arr = np.asarray(dev, np.float64)
        except CheckTimeout as e:
            self.resolved += 1
            return self._rescue(step, str(e))
        errs = _to_rel_err(arr)
        rep = report_from_errs(entries, errs, self.thresholds_for(step),
                               missing=missing, thr_scale=self.scales(step),
                               merge_problems=merge_problems)
        self.resolved += 1
        return StepCheck(step, rep)

    def _rescue(self, step: int, why: str) -> StepCheck:
        """Escalation past the watchdog ladder: recompute the check
        synchronously from retained host traces (``fallback``, wired to the
        supervisor's trace ring).  Evidence gone too -> the check is LOST —
        reported loudly in the step's record, run keeps progressing."""
        if self.fallback is not None:
            try:
                chk = self.fallback(step)
                self.rescued += 1
                if self.watchdog is not None:
                    self.watchdog.event("sync_fallback", step,
                                        "recomputed from trace ring")
                return chk
            except KeyError as e:
                why = f"{why}; fallback: {e}"
        self.lost += 1
        if self.watchdog is not None:
            self.watchdog.event("check_lost", step, why)
        rep = Report(missing=[f"check lost at step {step}: {why}"])
        return StepCheck(step, rep)
