"""Double-buffered async checking for the streaming supervisor.

Synchronous per-step checking (``compare_traces`` on the training loop)
serializes: dispatch the reduction, BLOCK for the ``(N, 2)`` scalars, build
the report, only then dispatch step k+1 — host and device take turns idling.
This pipeline splits the check into the two passes the checker already
exposes:

* at ``submit(k)`` the metadata pass runs (no transfer) and the whole-trace
  pair reduction is dispatched on device (``relerr_engine.sq_norms_async``) —
  the returned ``jax.Array`` is held as a future;
* resolution (host transfer of N x 2 scalars + threshold comparison +
  localization) happens when the entry leaves the bounded in-flight window,
  by which time step k+1's compute has been dispatched behind it.

The window is the backpressure bound: at most ``window`` step reductions
(and the trace leaves they reference) are in flight; submitting beyond it
resolves the oldest entry first, so device memory for pending checks stays
O(window), never O(run length).

Thresholds are estimated once at step 0 (paper §5); multi-step checking
needs two allowances on top:

* per-step kinds (activations / gradients) see batch-to-batch variation of
  the true FP-noise level that a single-batch estimate misses — measured at
  up to ~8x on clean runs — so they get a constant widening
  (``SUPERVISED_KIND_MULT``, bug errors sit ~100-1000x above thresholds);
* both sides accumulate independent round-off as states evolve, so every
  threshold additionally grows by ``1 + drift_alpha * step``.

``param_post_step`` keeps multiplier 1.0: the post-step parameter comparison
is cumulative state, empirically flat on clean runs (~0.1x threshold), and
it is exactly the signal that catches slow update-path drift — widening it
would blind the supervisor to the bugs it exists for.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass

import numpy as np

from repro.core import canonical as C
from repro.core.checker import (DEFAULT_KINDS, Report, collect_section_pairs,
                                report_from_errs)
from repro.core.relerr_engine import _to_rel_err, sq_norms_async
from repro.core.thresholds import Thresholds

SUPERVISED_KIND_MULT = {
    C.KIND_ACT: 8.0,
    C.KIND_ACT_GRAD: 8.0,
    C.KIND_PARAM_GRAD: 16.0,
    C.KIND_MAIN_GRAD: 16.0,
    C.KIND_PARAM_POST: 1.0,
}


@dataclass
class StepCheck:
    """One resolved online check: the step index and its report."""
    step: int
    report: Report

    @property
    def flagged(self) -> bool:
        return not self.report.passed


class AsyncCheckPipeline:
    """Bounded-window async differential checking over a supervised run."""

    def __init__(self, thresholds: Thresholds, window: int = 2,
                 kinds=DEFAULT_KINDS, kind_mult=None,
                 drift_alpha: float = 0.125):
        self.thresholds = thresholds
        self.window = max(0, int(window))
        self.kinds = kinds
        self.kind_mult = dict(SUPERVISED_KIND_MULT if kind_mult is None
                              else kind_mult)
        self.drift_alpha = drift_alpha
        self._inflight: deque = deque()
        self.submitted = 0
        self.resolved = 0
        self.max_in_flight = 0

    # ---- threshold schedule ------------------------------------------------
    def scales(self, step: int) -> dict:
        """Per-kind threshold scale at ``step``.  Step 0 compares identical
        states on the estimation batch — exact single-step semantics."""
        if step == 0:
            return {k: 1.0 for k in self.kinds}
        growth = 1.0 + self.drift_alpha * step
        return {k: self.kind_mult.get(k, 1.0) * growth for k in self.kinds}

    # ---- pipeline ----------------------------------------------------------
    @property
    def in_flight(self) -> int:
        return len(self._inflight)

    def submit(self, step: int, ref, cand) -> list[StepCheck]:
        """Enqueue the step-``step`` check; returns any checks that the
        backpressure bound forced to resolve (oldest first)."""
        entries, la, lb, missing = collect_section_pairs(ref, cand,
                                                         self.kinds)
        dev = sq_norms_async(la, lb)
        self._inflight.append((step, entries, missing, dev))
        self.submitted += 1
        done = []
        while len(self._inflight) > self.window:
            done.append(self._resolve())
        self.max_in_flight = max(self.max_in_flight, len(self._inflight))
        return done

    def poll(self) -> list[StepCheck]:
        """Resolve (only) entries whose device reduction already finished —
        free progress on steps where nothing was submitted."""
        done = []
        while self._inflight:
            dev = self._inflight[0][3]
            ready = getattr(dev, "is_ready", None)
            if ready is None or not ready():
                break
            done.append(self._resolve())
        return done

    def drain(self) -> list[StepCheck]:
        """Resolve everything still in flight (end of run)."""
        done = []
        while self._inflight:
            done.append(self._resolve())
        return done

    def check_sync(self, step: int, ref, cand) -> StepCheck:
        """Synchronous one-step check with the supervised threshold schedule
        (the bisection replay path, and the ``--async-window 0`` mode)."""
        entries, la, lb, missing = collect_section_pairs(ref, cand,
                                                         self.kinds)
        errs = _to_rel_err(np.asarray(sq_norms_async(la, lb), np.float64))
        rep = report_from_errs(entries, errs, self.thresholds,
                               missing=missing, thr_scale=self.scales(step))
        return StepCheck(step, rep)

    def _resolve(self) -> StepCheck:
        step, entries, missing, dev = self._inflight.popleft()
        errs = _to_rel_err(np.asarray(dev, np.float64))
        rep = report_from_errs(entries, errs, self.thresholds,
                               missing=missing, thr_scale=self.scales(step))
        self.resolved += 1
        return StepCheck(step, rep)
