"""Streaming training supervisor: online TTrace over multi-step runs.

The paper's workflow (§3) checks ONE training step; the silent bugs it
targets — stale ZeRO updates, drifting tied embeddings, stale FP8 scales —
express across *many* optimizer steps.  This subsystem runs reference and
candidate training loops in lockstep over N steps and checks every step
online:

* ``runner``   — the lockstep driver (``Supervisor``): one compiled step per
  side, params/opt_state threaded through, periodic checkpoints;
* ``pipeline`` — double-buffered async checking: step-k reductions enqueue on
  device while step k+1 trains, bounded in-flight window with backpressure;
* ``store``    — spill-to-disk trace ring buffer (sharded-npz manifests);
  flagged steps are pinned, memory stays flat over long runs;
* ``bisect``   — checkpoint bisection + sync replay to the FIRST bad step,
  handing that step to the existing rewrite-mode localizer;
* ``journal``  — append-only fsync'd per-step record; a SIGKILLed run
  resumes from it (``Supervisor.resume``) and converges to the same
  verdicts and first-bad-step as an uninterrupted run;
* ``watchdog`` — timeout/retry/sync-fallback ladder around host-blocking
  waits, plus graceful degradation of checking to sampling when the
  pipeline saturates;
* ``faults``   — the loud-fault injection registry (crash, hung check,
  NaN step, corrupt spill/checkpoint, dead writer) the above is
  evaluated against.
"""
from repro.supervise.bisect import (  # noqa: F401
    BisectResult, CheckpointKeeper, bisect_first_bad)
from repro.supervise.faults import (  # noqa: F401
    FAULTS, FaultInjector, FaultSpec, make_injector)
from repro.supervise.journal import (  # noqa: F401
    Journal, JournalState, journal_path)
from repro.supervise.pipeline import (  # noqa: F401
    REESTIMATED_KIND_MULT, SUPERVISED_KIND_MULT, AsyncCheckPipeline,
    StepCheck)
from repro.supervise.runner import (  # noqa: F401
    CandidateStep, SuperviseConfig, SuperviseResult, Supervisor)
from repro.supervise.store import (  # noqa: F401
    BackgroundWriter, TraceRing, WriterDeath, load_trace, save_trace)
from repro.supervise.watchdog import (  # noqa: F401
    BoundaryTimeout, CheckTimeout, DegradationController, LoudFault,
    Watchdog, WatchdogEvent, wait_ready)
