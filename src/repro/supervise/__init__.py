"""Streaming training supervisor: online TTrace over multi-step runs.

The paper's workflow (§3) checks ONE training step; the silent bugs it
targets — stale ZeRO updates, drifting tied embeddings, stale FP8 scales —
express across *many* optimizer steps.  This subsystem runs reference and
candidate training loops in lockstep over N steps and checks every step
online:

* ``runner``   — the lockstep driver (``Supervisor``): one compiled step per
  side, params/opt_state threaded through, periodic checkpoints;
* ``pipeline`` — double-buffered async checking: step-k reductions enqueue on
  device while step k+1 trains, bounded in-flight window with backpressure;
* ``store``    — spill-to-disk trace ring buffer (sharded-npz manifests);
  flagged steps are pinned, memory stays flat over long runs;
* ``bisect``   — checkpoint bisection + sync replay to the FIRST bad step,
  handing that step to the existing rewrite-mode localizer.
"""
from repro.supervise.bisect import BisectResult, bisect_first_bad  # noqa: F401
from repro.supervise.pipeline import (  # noqa: F401
    REESTIMATED_KIND_MULT, SUPERVISED_KIND_MULT, AsyncCheckPipeline,
    StepCheck)
from repro.supervise.runner import (  # noqa: F401
    CandidateStep, SuperviseConfig, SuperviseResult, Supervisor)
from repro.supervise.store import TraceRing, load_trace, save_trace  # noqa: F401
