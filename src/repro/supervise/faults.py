"""Loud-fault injection registry — the evaluation surface for fault
tolerance (sibling of ``bugs/registry.py``, which injects *silent* bugs).

Where the bug registry proves the checker catches wrong *numerics*, this
registry proves the supervisor survives wrong *machinery*: the process
dying, a device future hanging, NaN poisoning a step, disk payloads
rotting.  Each fault names a hook site inside the supervised loop; the
``FaultInjector`` is threaded through the supervisor (``--fault NAME
--fault-step K`` on the CLI) and fires at its site when the step matches.

Faults and their expected recovery:

* ``crash``             — SIGKILL at the top of step K; recovery is
  ``Supervisor.resume`` from the journal + last durable checkpoint.
* ``hang_check``        — every check future from step K on never becomes
  ready; the watchdog ladder rescues each (sync recompute from the trace
  ring) and sustained saturation degrades checking to sampling.
* ``nan_step``          — NaN/Inf poisons the candidate trace (loss +
  first activation) at step K; classified as a LOUD failure by the
  checker, localized, reported separately from threshold flags.
* ``corrupt_spill``     — bytes of step K's spilled candidate payload are
  flipped after the write; the checksum rejects the payload at load.
* ``truncate_ckpt``     — the step-K checkpoint loses the tail of a shard;
  detected at load, bisection falls back to an earlier checkpoint.
* ``dead_spill_writer`` — the background spill-writer thread dies at step
  K; the ring re-raises the stored error on the next ``put``/``get`` and
  restarts the worker.
"""
from __future__ import annotations

import os
import signal
import time
from dataclasses import dataclass
from typing import Callable, Optional

import numpy as np


@dataclass(frozen=True)
class FaultSpec:
    fault_id: str
    description: str
    site: str            # hook site inside the supervised loop
    sticky: bool = False  # fire at every step >= K (else exactly at K)
    recovery: str = ""    # what tolerating this fault looks like


FAULTS: dict[str, FaultSpec] = {f.fault_id: f for f in [
    FaultSpec("crash",
              "SIGKILL the supervisor process at the top of step K",
              site="step_start",
              recovery="journaled resume from the last durable checkpoint"),
    FaultSpec("hang_check",
              "check futures from step K on never become ready",
              site="check_future", sticky=True,
              recovery="watchdog sync-fallback per check; sustained "
                       "saturation degrades checking to sampling"),
    FaultSpec("nan_step",
              "NaN poisons the candidate loss + first activation at step K",
              site="cand_trace",
              recovery="classified LOUD by the checker, localized, "
                       "reported separately from threshold flags"),
    FaultSpec("corrupt_spill",
              "flip bytes of step K's spilled candidate payload",
              site="post_spill",
              recovery="checksum rejects the payload at load"),
    FaultSpec("truncate_ckpt",
              "truncate a shard of the step-K checkpoint",
              site="post_ckpt",
              recovery="checksum rejects the restore; bisection falls "
                       "back to an earlier checkpoint"),
    FaultSpec("dead_spill_writer",
              "kill the background spill-writer thread at step K",
              site="spill_writer",
              recovery="ring re-raises the writer error on next put/get "
                       "and restarts the worker"),
]}


class _HungFuture:
    """A device-future stand-in that never resolves: ``is_ready`` stays
    False and any materialization attempt blocks past every watchdog
    timeout (the watchdog abandons the worker thread stuck here)."""

    def __init__(self, inner):
        self._inner = inner

    def is_ready(self) -> bool:
        return False

    def __array__(self, dtype=None):
        time.sleep(3600.0)
        raise RuntimeError("hung future materialized past the watchdog")


def make_injector(fault: Optional[str], fault_step: Optional[int],
                  crash_handler: Optional[Callable[[], None]] = None
                  ) -> Optional["FaultInjector"]:
    """Validate and build an injector (the CLI's refusal path lives here).

    Raises ``ValueError`` for an unknown fault name, a missing step, or a
    negative step — never silently ignores a malformed spec."""
    if fault is None:
        if fault_step is not None:
            raise ValueError("--fault-step given without --fault")
        return None
    if fault not in FAULTS:
        raise ValueError(f"unknown fault {fault!r} — registered faults: "
                         f"{', '.join(sorted(FAULTS))}")
    if fault_step is None:
        raise ValueError(f"--fault {fault} needs --fault-step K "
                         f"(the step the fault fires at)")
    if fault_step < 0:
        raise ValueError(f"--fault-step must be >= 0, got {fault_step}")
    return FaultInjector(fault, fault_step, crash_handler=crash_handler)


class FaultInjector:
    """One armed fault, fired by the supervisor's hook sites.

    ``crash_handler`` defaults to a true SIGKILL (the CLI path); tests
    inject a raising handler to simulate the kill in-process — the journal
    fsyncs every record, so an abrupt abort at the same point is
    indistinguishable from the signal."""

    def __init__(self, fault_id: str, step: int,
                 crash_handler: Optional[Callable[[], None]] = None):
        self.spec = FAULTS[fault_id]
        self.step = int(step)
        self.fired = 0
        self.crash_handler = crash_handler or self._sigkill

    @staticmethod
    def _sigkill() -> None:
        os.kill(os.getpid(), signal.SIGKILL)

    def fires(self, site: str, step: int) -> bool:
        if site != self.spec.site:
            return False
        hit = step >= self.step if self.spec.sticky else step == self.step
        return hit

    # ---- sites -------------------------------------------------------------
    def step_start(self, step: int) -> None:
        if self.fires("step_start", step):
            self.fired += 1
            self.crash_handler()

    def check_future(self, step: int, dev):
        if self.fires("check_future", step):
            self.fired += 1
            return _HungFuture(dev)
        return dev

    def cand_trace(self, step: int, trace):
        if self.fires("cand_trace", step):
            self.fired += 1
            trace.loss = float("nan")
            acts = trace.section("activation")
            for name in acts:
                acts[name] = np.full(acts.shape_of(name), np.nan,
                                     np.float32)
                break
        return trace

    def post_spill(self, step: int, root: str) -> None:
        """Flip bytes in the middle of the candidate payload's first
        shard — a checksum-detectable corruption, not a missing file."""
        if not self.fires("post_spill", step):
            return
        self.fired += 1
        _corrupt_first_shard(os.path.join(root, "cand"))

    def post_ckpt(self, step: int, root: str) -> None:
        if not self.fires("post_ckpt", step):
            return
        self.fired += 1
        shard = _first_shard(root)
        if shard is not None:
            size = os.path.getsize(shard)
            with open(shard, "r+b") as f:
                f.truncate(max(size // 2, 1))

    def spill_writer(self, step: int) -> Optional[BaseException]:
        if self.fires("spill_writer", step):
            self.fired += 1
            from repro.supervise.store import WriterDeath
            return WriterDeath(
                f"injected spill-writer death at step {step}")
        return None


def _first_shard(root: str) -> Optional[str]:
    try:
        shards = sorted(f for f in os.listdir(root)
                        if f.startswith("shard_"))
    except FileNotFoundError:
        return None
    return os.path.join(root, shards[0]) if shards else None


def _corrupt_first_shard(root: str) -> None:
    shard = _first_shard(root)
    if shard is None:
        return
    size = os.path.getsize(shard)
    with open(shard, "r+b") as f:
        f.seek(size // 2)
        chunk = f.read(8)
        f.seek(size // 2)
        f.write(bytes(b ^ 0xFF for b in chunk) or b"\xff")
