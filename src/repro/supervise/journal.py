"""Supervision journal: the durable record a killed run resumes from.

A supervised run's state is scattered across a process (async pipeline
entries, threshold epochs, the result-in-progress) and a work dir
(checkpoints, spilled traces).  The process half dies with a SIGKILL; the
journal makes it reconstructible: an append-only, per-record-checksummed,
fsync'd JSONL file in the work dir recording every durable fact the loop
establishes —

* ``step``    — step k trained on both sides (and whether a check was
  submitted for it, so resume knows which verdicts to expect);
* ``verdict`` — the resolved online check of step k, full ``Report``
  payload (records, merge problems, localization);
* ``epoch``   — a threshold epoch settled into the pipeline (the merged
  per-tensor estimates + kind multipliers, keyed by its from-step);
* ``ckpt`` / ``spill`` — a checkpoint / trace-spill landed on disk;
* ``degrade`` / ``recover`` / ``watchdog`` / ``loud`` — watchdog
  escalations, sampling-degradation transitions and loud-failure events;
* ``start`` / ``resume`` / ``end`` — run lifecycle (the ``start`` record
  pins the determinism-relevant config so a mismatched resume is refused).

Each line is ``<json>\\t<crc32 of the json text>``: a torn tail write (the
usual SIGKILL artifact) fails its checksum and reading stops there — every
record BEFORE the tear was fsync'd and is trusted.  ``Supervisor.resume``
replays the journal to rebuild ``SuperviseResult`` verdicts and the
pipeline's threshold-epoch schedule, picks the newest durable checkpoint
consistent with the journaled history, and re-enters the lockstep loop
from it; determinism of the loop (stateless batch generator, bit-exact
checkpoint restore, once-compiled steps) makes the resumed run converge to
the same verdicts and first-bad-step as an uninterrupted one.
"""
from __future__ import annotations

import json
import os
import queue
import threading
import zlib
from typing import Any, Optional

from repro.core.checker import CheckRecord, Report
from repro.core.thresholds import Thresholds

JOURNAL_NAME = "journal.jsonl"


def journal_path(work_dir: str) -> str:
    return os.path.join(work_dir, JOURNAL_NAME)


class Journal:
    """Append-only fsync'd event log with per-record checksums.

    ``append`` only enqueues the record — serialization, the page-cache
    write, and the ``os.fsync`` all happen on a dedicated writer thread
    that group-commits: one fsync covers every record drained since the
    last one.  The hot loop therefore never blocks on a syscall or a
    thread wake (on a saturated 2-core host even a 2 KB write costs
    milliseconds of scheduling latency, and fsync tail latency on shared
    disks is bimodal).  A SIGKILL loses at most the records still queued
    or since the last commit, which the resume machinery already
    tolerates: the reader stops at the torn tail and ``resume_step``
    simply picks an earlier durable checkpoint — late durability costs
    resume *distance*, never verdict correctness.  ``close`` drains the
    queue, so any in-process read-after-close sees every record."""

    _CLOSE = object()

    def __init__(self, path: str, fsync: bool = True):
        self.path = path
        self.fsync = fsync
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        self._f = open(path, "a", encoding="utf-8")
        self._q: queue.SimpleQueue = queue.SimpleQueue()
        self._closed = False
        self._lock = threading.Lock()   # background writers journal too
        self.appended = 0
        self.syncs = 0
        self._writer = threading.Thread(target=self._write_loop,
                                        name="journal-writer", daemon=True)
        self._writer.start()

    def append(self, etype: str, **fields: Any) -> None:
        with self._lock:
            if self._closed:
                # end-of-run teardown: a background writer landing after
                # close() (or a post-run diagnosis call) has nothing
                # durable left to record — the run already ended
                return
            self._q.put({"t": etype, **fields})
            self.appended += 1

    @staticmethod
    def _encode(rec: dict) -> str:
        text = json.dumps(rec, separators=(",", ":"))
        return f"{text}\t{zlib.crc32(text.encode()):08x}\n"

    def _write_loop(self) -> None:
        while True:
            rec = self._q.get()
            if rec is Journal._CLOSE:
                break
            batch = [rec]
            while True:            # group-commit everything already queued
                try:
                    nxt = self._q.get_nowait()
                except queue.Empty:
                    break
                if nxt is Journal._CLOSE:
                    batch.append(None)
                    break
                batch.append(nxt)
            closing = batch and batch[-1] is None
            if closing:
                batch.pop()
            try:
                self._f.writelines(self._encode(r) for r in batch)
                self._f.flush()
                if self.fsync:
                    os.fsync(self._f.fileno())
                    self.syncs += 1
            except (OSError, ValueError):
                return             # file gone under us: teardown race
            if closing:
                break

    def close(self) -> None:
        with self._lock:
            if self._closed:
                return
            self._closed = True
        self._q.put(Journal._CLOSE)
        self._writer.join(timeout=10.0)
        if not self._f.closed:
            self._f.close()

    # ---- reading -----------------------------------------------------------
    @staticmethod
    def read(path: str) -> list[dict]:
        """Replay the journal; stops at the first torn/corrupt record (a
        SIGKILL mid-append) — everything before it was fsync'd and valid."""
        events: list[dict] = []
        if not os.path.exists(path):
            return events
        with open(path, encoding="utf-8") as f:
            for line in f:
                text, _, crc = line.rstrip("\n").rpartition("\t")
                if not text:
                    break
                try:
                    if int(crc, 16) != zlib.crc32(text.encode()):
                        break
                    events.append(json.loads(text))
                except (ValueError, json.JSONDecodeError):
                    break
        return events


# ---------------------------------------------------------------------------
# payload (de)serialization
# ---------------------------------------------------------------------------

def report_to_payload(rep: Optional[Report]) -> Optional[dict]:
    if rep is None:
        return None
    return {
        "records": [[r.kind, r.name, r.rel_err, r.threshold,
                     bool(r.flagged), r.note] for r in rep.records],
        "merge_problems": list(rep.merge_problems),
        "missing": list(rep.missing),
        "localized": rep.localized,
        "mode": rep.localization_mode,
    }


def report_from_payload(p: Optional[dict]) -> Optional[Report]:
    if p is None:
        return None
    rep = Report(records=[CheckRecord(k, n, float(e), float(t), bool(fl),
                                      note)
                          for k, n, e, t, fl, note in p["records"]],
                 merge_problems=list(p["merge_problems"]),
                 missing=list(p["missing"]))
    rep.localized = p["localized"]
    rep.localization_mode = p["mode"]
    return rep


def thresholds_to_payload(thr: Thresholds) -> dict:
    return {"eps": thr.eps, "margin": thr.margin,
            "floor_mult": thr.floor_mult,
            "per_tensor": {k: dict(v) for k, v in thr.per_tensor.items()}}


def thresholds_from_payload(p: dict) -> Thresholds:
    return Thresholds(eps=float(p["eps"]), margin=float(p["margin"]),
                      floor_mult=float(p["floor_mult"]),
                      per_tensor={k: {n: float(e) for n, e in v.items()}
                                  for k, v in p["per_tensor"].items()})


# ---------------------------------------------------------------------------
# resume-state reconstruction
# ---------------------------------------------------------------------------

class JournalState:
    """Everything ``Supervisor.resume`` needs, replayed from the journal."""

    #: ``start``-record fields that must match the resuming supervisor's
    #: config — a drifted value would silently change verdicts
    CONFIG_KEYS = ("steps", "check_every", "async_window", "ckpt_every",
                   "reestimate_every", "seed", "drift_alpha")

    def __init__(self, events: list[dict]):
        self.events = events
        self.start: Optional[dict] = None
        self.verdicts: dict[int, Optional[Report]] = {}
        self.checked_steps: set[int] = set()
        self.trained_steps: set[int] = set()
        self.epochs: list[tuple[int, Thresholds, dict]] = []
        self.reestimations = 0
        self.resumes = 0
        self.degradations: list[dict] = []
        self.loud: list[dict] = []
        for ev in events:
            t = ev["t"]
            if t == "start" and self.start is None:
                self.start = ev
            elif t == "step":
                self.trained_steps.add(int(ev["step"]))
                if ev.get("checked"):
                    self.checked_steps.add(int(ev["step"]))
            elif t == "verdict":
                self.verdicts[int(ev["step"])] = report_from_payload(
                    ev["report"])
            elif t == "epoch":
                self.epochs.append((int(ev["from_step"]),
                                    thresholds_from_payload(ev["thresholds"]),
                                    dict(ev["kind_mult"])))
                if ev.get("reestimated"):
                    self.reestimations += 1
            elif t == "resume":
                self.resumes += 1
            elif t in ("degrade", "recover"):
                self.degradations.append(ev)
            elif t == "loud":
                self.loud.append(ev)

    @property
    def last_trained(self) -> int:
        return max(self.trained_steps, default=-1)

    def config_mismatches(self, config: dict) -> list[str]:
        if self.start is None:
            return []
        return [f"{k}: journal={self.start.get(k)!r} run={config.get(k)!r}"
                for k in self.CONFIG_KEYS
                if self.start.get(k) != config.get(k)]

    def resume_step(self, durable_ckpts: list[int]) -> int:
        """The newest checkpoint the run can restart from and still converge
        to the uninterrupted run's verdicts: every check submitted for a
        step BELOW it must have a journaled verdict (unresolved in-flight
        checks died with the process and must be recomputed), and every
        re-estimation step below it must have a journaled (settled) epoch —
        an estimate still pending at the kill died in flight, and only
        re-running its step can reproduce it."""
        R = (int(self.start.get("reestimate_every") or 0)
             if self.start else 0)
        settled = {s for s, _, _ in self.epochs}
        best = 0
        for c in sorted(durable_ckpts):
            if c > self.last_trained + 1:
                break
            if any(s not in self.verdicts
                   for s in self.checked_steps if s < c):
                break
            if R and any(e not in settled for e in range(R, c, R)):
                break
            best = c
        return best

    def epochs_below(self, step: int) -> list[tuple[int, Thresholds, dict]]:
        return [(s, thr, km) for s, thr, km in self.epochs if 0 < s < step]

    def flagged_below(self, step: int) -> list[int]:
        return sorted(s for s, rep in self.verdicts.items()
                      if s < step and rep is not None and not rep.passed)
