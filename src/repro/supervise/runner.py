"""Multi-step supervisor: online TTrace over a whole training run.

``Supervisor`` threads (params, opt_state) through BOTH the single-device
reference and the distributed candidate for N steps, using exactly one
compiled step per side (``collector.make_trace_step`` / the recipe's
``CandidateStep`` — no re-tracing, no re-jitting per step), and checks
every step online through the async pipeline.  With ``overlap=True`` (the
default) every non-training cost rides off the critical path: the
reference step dispatches on a spare device concurrently with the
candidate, spill writes run on a background thread, and threshold
re-estimation resolves like an async check — all bit-identical to the
lockstep path (``overlap=False``), which exists for A/B timing and the
determinism tests:

    step k trains  ->  step-k reductions enqueue on device  ->  step k+1
    trains while step k's N x 2 scalars are still in flight  ->  the
    bounded window resolves step k's report

The candidate side is RECIPE-GENERIC: ``CandidateStep`` is the contract —
a once-compiled stateful train step plus a runner factory for rewrite-mode
localization and the recipe's machine epsilon — and ``CandidateStep.build``
dispatches on the ``ParallelConfig`` to the shard_map candidate (dense /
MoE / ZeRO-1), the pipeline-parallel candidate (``parallel.pp``) or the FP8
recipes (``precision.fp8``, checked under BF16 epsilon per paper §6.7).

With ``reestimate_every=R`` the supervised loop additionally re-runs the
fused pair-step threshold estimate on the live batch every R steps and
swaps the (union-merged) thresholds into the async pipeline — margins then
tighten from the coarse ``SUPERVISED_KIND_MULT`` constants to
``REESTIMATED_KIND_MULT``, back toward the paper's single-step 8x.

On a flag the run is bisected to the FIRST bad step (checkpoint binary
search + deterministic sync replay, ``supervise.bisect``) and that step is
handed to the paper's localization machinery — propagation/backward/
optimizer modes from the step report, plus rewrite-mode module isolation
when the divergence is in the forward pass.  This is the paper's §3
workflow (steps 1-5) run as a loop over the whole training run instead of
a single snapshot.
"""
from __future__ import annotations

import os
import tempfile
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

from repro.checkpoint.store import ChecksumError
from repro.core import canonical as C
from repro.core.checker import Report, localize_with_rewrites
from repro.core.collector import make_trace_step
from repro.core.harness import make_model_runner
from repro.core.relerr_engine import batched_rel_err
from repro.core.thresholds import (MACHINE_EPS, Thresholds,
                                   estimate_thresholds, make_pair_estimator)
from repro.data.synthetic import make_batch
from repro.parallel.api import (ParallelConfig, make_candidate_runner,
                                make_candidate_train_step)
from repro.supervise.bisect import (BisectResult, CheckpointKeeper,
                                    bisect_first_bad)
from repro.supervise.faults import FaultInjector
from repro.supervise.journal import (Journal, JournalState, journal_path,
                                     report_to_payload, thresholds_to_payload)
from repro.supervise.pipeline import (REESTIMATED_KIND_MULT,
                                      AsyncCheckPipeline, StepCheck)
from repro.supervise.store import TraceRing
from repro.supervise.watchdog import (DegradationController, Watchdog,
                                      WatchdogEvent)


@dataclass
class CandidateStep:
    """The recipe-generic candidate contract the supervisor drives.

    ``step(params, opt_state, batch) -> (Trace, new_params, new_opt_state)``
    must be a ONCE-compiled stateful train step (same compiled callable
    every supervised step and bisection replay); ``make_runner(params,
    opt_state)`` builds the one-shot ``runner(batch, rewrites) -> Trace``
    used for rewrite-mode localization at the first bad step; ``eps`` is
    the machine epsilon threshold estimation should use for this recipe
    (BF16's for FP8 recipes, paper §6.7).
    """
    step: Callable
    params0: Any
    opt_state0: Any
    make_runner: Callable
    eps: float = MACHINE_EPS["float32"]
    name: str = "candidate"
    # widening of the supervised per-step kind margins this recipe's
    # numerics need on top of the reference estimate (param_post exempt):
    # the 1F1B engine accumulates M per-microbatch partial reductions, a
    # reassociation the single-batch estimate cannot see
    kind_scale: float = 1.0

    @classmethod
    def build(cls, cfg, pcfg: ParallelConfig, params, opt,
              batch) -> "CandidateStep":
        """Dispatch on ``pcfg`` (shard_map / pp / 1F1B / fp8) via
        ``parallel.api``."""
        import math
        step, p0, s0 = make_candidate_train_step(cfg, pcfg, params, opt,
                                                 batch)
        eps = (MACHINE_EPS["float8_e4m3fn"] if pcfg.fp8
               else MACHINE_EPS["float32"])
        kind_scale = 1.0
        if pcfg.recipe_kind == "pp_1f1b":
            name = f"pp1f1b{pcfg.pp}x{pcfg.microbatches}"
            kind_scale = max(2.0, math.sqrt(pcfg.microbatches))
        elif pcfg.fp8:
            name = "fp8-" + pcfg.fp8
        elif pcfg.pp > 1:
            name = f"pp{pcfg.pp}"
        else:
            name = "shard_map"
        return cls(
            step=step, params0=p0, opt_state0=s0,
            make_runner=lambda p, s: make_candidate_runner(
                cfg, pcfg, p, opt, s),
            eps=eps, name=name, kind_scale=kind_scale)


@dataclass
class SuperviseConfig:
    steps: int = 8
    check_every: int = 1        # online check every C-th step; 0 = never
    async_window: int = 2       # in-flight device checks; 0 = synchronous
    # overlap everything off the training critical path: reference step on
    # its own (spare) device set dispatched concurrently with the
    # candidate, background spill writes, threshold re-estimation resolved
    # like an async check.  False = the lockstep path (same results
    # bit-for-bit; the determinism tests pin that)
    overlap: bool = True
    ckpt_every: int = 4         # periodic bisection checkpoints
    ckpt_keep: int = 16         # checkpoint count bound (log-spaced thinning)
    ring_window: int = 4        # live trace pairs kept in memory
    spill: bool = True          # spill evicted trace pairs to disk
    spill_keep: int = 8         # unpinned spilled steps retained on disk
    drift_alpha: float = 0.125  # per-step threshold growth allowance
    reestimate_every: int = 0   # re-run the fused pair estimate every R steps
    eps: Optional[float] = None  # None = auto (recipe eps; BF16 for FP8)
    margin: float = 8.0
    localize: bool = True       # rewrite-mode localization at the bad step
    stop_on_flag: bool = True   # end the run once a resolved check flags
    work_dir: Optional[str] = None   # checkpoints + spill (tmp if None)
    seed: int = 0
    # ---- fault tolerance ---------------------------------------------------
    journal: bool = True        # fsync'd per-step journal (resume support)
    watchdog_timeout_s: float = 60.0  # per-wait budget on check transfers
    watchdog_retries: int = 1   # retries before sync-fallback escalation
    degrade_after: int = 3      # consecutive saturated checks before sampling
    degrade_max_mult: int = 8   # cap on the effective check_every multiplier


@dataclass
class SuperviseResult:
    flagged: bool
    steps_run: int
    first_flagged_step: Optional[int]   # first ONLINE-checked step flagging
    first_bad_step: Optional[int]       # after bisection refinement
    checks: dict = field(default_factory=dict)   # step -> Report (resolved)
    bad_check: Optional[StepCheck] = None
    bisection: Optional[BisectResult] = None
    localization: Optional[Report] = None        # rewrite-mode report
    thresholds: Optional[Thresholds] = None
    reestimations: int = 0              # threshold epochs swapped in
    losses: list = field(default_factory=list)          # reference loss/step
    cand_losses: list = field(default_factory=list)
    timings: dict = field(default_factory=dict)
    work_dir: Optional[str] = None
    # ---- fault tolerance ---------------------------------------------------
    resumed_from: Optional[int] = None  # journaled-resume entry step
    loud_steps: list = field(default_factory=list)  # NaN/Inf-poisoned steps
    degradations: list = field(default_factory=list)  # degrade/recover events
    watchdog_events: list = field(default_factory=list)
    checks_rescued: int = 0     # timed-out checks recomputed synchronously
    checks_lost: int = 0        # timed-out checks whose evidence was gone
    degraded_check_every: Optional[int] = None  # final effective cadence

    @property
    def passed(self) -> bool:
        return not self.flagged

    @property
    def localized_module(self) -> Optional[str]:
        if self.localization is not None and self.localization.localized:
            return self.localization.localized
        if self.bad_check is not None and self.bad_check.report is not None:
            return self.bad_check.report.localized
        if self.first_flagged_step is not None:
            return self.checks[self.first_flagged_step].localized
        return None

    def summary(self, max_rows: int = 8) -> str:
        lines = []
        status = "PASS" if self.passed else "FAIL"
        lines.append(f"supervised run: {status} over {self.steps_run} steps "
                     f"({len(self.checks)} checked online)")
        if self.resumed_from is not None:
            lines.append(f"  resumed from journaled checkpoint at step "
                         f"{self.resumed_from}")
        if self.loud_steps:
            lines.append(f"  LOUD failures (NaN/Inf) at steps "
                         f"{sorted(self.loud_steps)}")
        if self.checks_rescued or self.checks_lost:
            lines.append(f"  watchdog: {self.checks_rescued} checks rescued "
                         f"by sync fallback, {self.checks_lost} lost")
        if self.degradations:
            lines.append(f"  degraded to sampling {len(self.degradations)}x "
                         f"(final effective check_every: "
                         f"{self.degraded_check_every})")
        if self.reestimations:
            lines.append(f"  thresholds re-estimated {self.reestimations}x "
                         f"on live batches")
        if self.flagged:
            lines.append(f"  first flagged (online): step "
                         f"{self.first_flagged_step}")
            if self.bisection is not None:
                lines.append("  " + self.bisection.summary())
            lines.append(f"  FIRST BAD STEP: {self.first_bad_step}")
            if self.bad_check is not None and self.bad_check.report:
                rep = self.bad_check.report
                for ln in rep.summary(max_rows=max_rows).splitlines():
                    lines.append("  " + ln)
            if self.localization is not None and self.localization.localized:
                lines.append(f"  LOCALIZED (rewrite): bug in module "
                             f"'{self.localization.localized}'")
        return "\n".join(lines)


class Supervisor:
    """Streaming lockstep supervisor for one (model, recipe) pairing.

    ``batch_fn(step) -> batch`` defaults to the deterministic synthetic
    generator, which is also what makes bisection replay exact.  Pass
    ``candidate`` to drive a custom ``CandidateStep``; by default one is
    built from ``pcfg`` (shard_map / pp / fp8).
    """

    def __init__(self, model, cfg, pcfg: ParallelConfig, opt,
                 params=None, scfg: Optional[SuperviseConfig] = None,
                 batch_fn: Optional[Callable[[int], dict]] = None,
                 batch_size: int = 4, seq_len: int = 32,
                 candidate: Optional[CandidateStep] = None,
                 log_fn: Optional[Callable[[str], None]] = None,
                 fault: Optional[FaultInjector] = None):
        import jax
        self.model, self.cfg, self.pcfg, self.opt = model, cfg, pcfg, opt
        self.scfg = scfg or SuperviseConfig()
        self.params0 = (params if params is not None
                        else model.init(jax.random.PRNGKey(self.scfg.seed)))
        self.batch_fn = batch_fn or (
            lambda step: make_batch(cfg, batch_size, seq_len,
                                    seed=self.scfg.seed, step=step))
        self.log = log_fn or (lambda s: None)
        self.work_dir = (self.scfg.work_dir
                         or tempfile.mkdtemp(prefix="ttrace_supervise_"))
        self.keeper = CheckpointKeeper(os.path.join(self.work_dir, "ckpt"),
                                       keep=self.scfg.ckpt_keep,
                                       background=self.scfg.overlap)
        self.keeper.on_save = self._on_ckpt_saved
        # a step's async check resolves at most async_window * check_every
        # puts after its own, and pinning happens at resolution — the ring
        # must still hold the step then, or flagged evidence is lost (the
        # "pinned steps are never dropped" contract).  check_every = 0 runs
        # no checks at all, so nothing constrains the ring (this used to
        # blow the window up to async_window * check_every and keep every
        # trace of the run live — the "checking off slower than checking
        # on" bench anomaly)
        if self.scfg.check_every > 0:
            min_window = min(self.scfg.async_window
                             * self.scfg.check_every + 1,
                             self.scfg.steps + 1)
        else:
            min_window = 1
        self.ring = TraceRing(
            window=max(self.scfg.ring_window, min_window),
            spill_dir=(os.path.join(self.work_dir, "spill")
                       if self.scfg.spill else None),
            spill_keep=self.scfg.spill_keep,
            background=self.scfg.overlap)
        self.candidate = candidate
        self.pipe: Optional[AsyncCheckPipeline] = None
        self._ref_step = None
        self._ref_state = self._cand_state = None
        self._estimator = None
        self._bad_entry = None
        # ---- fault tolerance ----------------------------------------------
        self.fault = fault
        self.journal: Optional[Journal] = None
        self.watchdog = Watchdog(self.scfg.watchdog_timeout_s,
                                 retries=self.scfg.watchdog_retries,
                                 on_event=self._on_wd_event)
        self.degrade = DegradationController(
            check_every=max(1, self.scfg.check_every),
            degrade_after=self.scfg.degrade_after,
            max_mult=self.scfg.degrade_max_mult,
            on_event=self._on_wd_event)
        self.ring.on_spill = self._on_spilled
        if fault is not None:
            self.ring.fault_hook = fault.spill_writer

    # ---- journal + watchdog plumbing ---------------------------------------
    def _j(self, etype: str, **fields) -> None:
        if self.journal is not None:
            self.journal.append(etype, **fields)

    def _config_dict(self) -> dict:
        sc = self.scfg
        return {k: getattr(sc, k) for k in JournalState.CONFIG_KEYS}

    def _on_wd_event(self, ev: WatchdogEvent) -> None:
        """Watchdog/degradation events: journaled + logged as they fire."""
        if ev.kind in ("degrade", "recover"):
            self._j(ev.kind, step=ev.step, detail=ev.detail)
        else:
            self._j("watchdog", step=ev.step, kind=ev.kind, detail=ev.detail)
        self.log(f"  [supervise] watchdog: {ev}")

    def _on_ckpt_saved(self, step: int, root: str) -> None:
        # fires on the checkpoint writer's thread once the write landed
        if self.fault is not None:
            self.fault.post_ckpt(step, root)
        self._j("ckpt", step=step)

    def _on_spilled(self, step: int, root: str) -> None:
        # fires on the spill writer's thread once both sides landed
        if self.fault is not None:
            self.fault.post_spill(step, root)
        self._j("spill", step=step)

    def _sync_from_ring(self, step: int) -> StepCheck:
        """The watchdog's escalation target: recompute a timed-out check
        synchronously from the retained host traces.  Raises ``KeyError``
        when the ring no longer holds the step (check is then LOST)."""
        ref_tr, cand_tr = self.ring.get(step)
        return self.pipe.check_sync(step, ref_tr, cand_tr)

    # ---- build (thresholds + compiled steps) -------------------------------
    def _ref_device(self):
        """The spare device the reference step (and the live threshold
        estimator) runs on — the device partition of the overlapped loop.
        None (shared placement) when nothing is spare or overlap is off."""
        from repro.parallel.api import spare_host_device
        return spare_host_device(self.pcfg) if self.scfg.overlap else None

    def _build(self):
        sc = self.scfg
        batch0 = self.batch_fn(0)
        t0 = time.perf_counter()
        if self.candidate is None:
            self.candidate = CandidateStep.build(self.cfg, self.pcfg,
                                                 self.params0, self.opt,
                                                 batch0)
        eps = sc.eps if sc.eps is not None else self.candidate.eps
        self.eps = eps
        ref_runner = make_model_runner(self.model, self.params0, self.opt,
                                       self.opt.init(self.params0))
        thr, _ = estimate_thresholds(ref_runner, batch0, eps, sc.margin,
                                     sc.seed)
        t_thr = time.perf_counter() - t0
        # margins start at the constant widening either way: until the first
        # live re-estimation lands, only the step-0 estimate exists and the
        # full batch-to-batch allowance is still needed
        self.pipe = AsyncCheckPipeline(thr, window=sc.async_window,
                                       drift_alpha=sc.drift_alpha,
                                       kind_scale=self.candidate.kind_scale)
        self.pipe.watchdog = self.watchdog
        self.pipe.fallback = self._sync_from_ring
        self.pipe.on_epoch = lambda s, t, km: self._j(
            "epoch", from_step=s, thresholds=thresholds_to_payload(t),
            kind_mult=km, reestimated=True)
        if self.fault is not None:
            self.pipe.tap_future = self.fault.check_future

        def loss_call(p, b, ctx):
            return self.model.loss(p, b, ctx=ctx)[0]

        t0 = time.perf_counter()
        ref_dev = self._ref_device()
        self._ref_step = make_trace_step(loss_call, self.opt, self.params0,
                                         batch0, device=ref_dev)
        self._ref_state = (self.params0, self.opt.init(self.params0))
        self._cand_state = (self.candidate.params0,
                            self.candidate.opt_state0)
        timings = {"thresholds_s": t_thr}
        if sc.reestimate_every:
            self._estimator = make_pair_estimator(
                loss_call, self.opt, self.params0, batch0, eps, sc.margin,
                sc.seed, device=ref_dev)
            # compile (and discard) one estimate now: the first live epoch
            # would otherwise carry seconds of jit time INSIDE the steady
            # loop — the dominant share of the old reest_async2 overhead
            t1 = time.perf_counter()
            self._estimator(self._ref_state[0], self._ref_state[1], batch0)
            timings["estimator_warmup_s"] = time.perf_counter() - t1
        timings["build_s"] = time.perf_counter() - t0
        return thr, timings

    # ---- periodic threshold re-estimation ----------------------------------
    def _reestimate(self, k: int, rp, rs, batch, res: SuperviseResult):
        """Dispatch the live-batch pair estimate and register it as a
        PENDING threshold epoch: the device computation overlaps the
        training steps behind it, and the pipeline resolves it the moment a
        check at step >= k needs the epoch (or opportunistically once the
        reduction is ready) — bit-identical thresholds to the synchronous
        stall, none of the stall.  From the first live estimate on, the
        union tracks the real noise level and the constant widening
        tightens to the re-estimated multipliers (steps before this keep
        SUPERVISED_KIND_MULT)."""
        t0 = time.perf_counter()
        resolve = self._estimator.submit(rp, rs, batch, step=k)
        self.pipe.schedule_epoch(k, resolve,
                                 kind_mult=REESTIMATED_KIND_MULT)
        if not self.scfg.overlap:
            self.pipe.settle_epochs(k)       # the lockstep path blocks here
        res.reestimations += 1
        res.timings["reestimate_s"] = (res.timings.get("reestimate_s", 0.0)
                                       + time.perf_counter() - t0)
        self.log(f"  [supervise] step {k}: live-batch threshold estimate "
                 f"dispatched (epoch {res.reestimations})")

    # ---- main loop ---------------------------------------------------------
    def run(self) -> SuperviseResult:
        sc = self.scfg
        thr, timings = self._build()
        res = SuperviseResult(flagged=False, steps_run=0,
                              first_flagged_step=None, first_bad_step=None,
                              thresholds=thr, work_dir=self.work_dir)
        res.timings = timings
        if sc.journal:
            self.journal = Journal(journal_path(self.work_dir))
            self._j("start", **self._config_dict())
        return self._run_loop(res, start=0, flagged_steps=[],
                              entry=(self._ref_state, self._cand_state))

    def resume(self) -> SuperviseResult:
        """Re-enter a killed supervised run from its journal + work dir.

        Replays the journal to rebuild resolved verdicts and the settled
        threshold-epoch schedule, restores both sides from the newest
        DURABLE checkpoint consistent with that history (CRC-verified;
        torn writes from the crash are discarded loudly), and re-enters
        the lockstep loop there.  Determinism of the loop (stateless batch
        generator, bit-exact restore, once-compiled steps) makes the
        resumed run converge to the same flagged steps, rel-errs,
        threshold epochs and first-bad-step as an uninterrupted run —
        only per-step host losses before the resume point are NaN
        placeholders (the journal deliberately never syncs device losses).
        """
        sc = self.scfg
        if not sc.work_dir:
            raise ValueError("resume() needs scfg.work_dir — the journal "
                             "and checkpoints of the run to resume")
        js = JournalState(Journal.read(journal_path(self.work_dir)))
        mism = js.config_mismatches(self._config_dict())
        if mism:
            raise ValueError("refusing to resume with a drifted config "
                             "(verdicts would silently change): "
                             + "; ".join(mism))
        thr, timings = self._build()
        # durable checkpoints: on disk AND CRC-clean — a write torn by the
        # crash is discarded here, loudly
        self.keeper.rescan()
        for s in list(self.keeper.steps):
            if not self.keeper.verify(s):
                self.watchdog.event("loud", s,
                                    "corrupt checkpoint discarded at resume")
                self.keeper.discard(s)
        self.ring.rescan()
        start = js.resume_step(self.keeper.steps)
        res = SuperviseResult(flagged=False, steps_run=0,
                              first_flagged_step=None, first_bad_step=None,
                              thresholds=thr, work_dir=self.work_dir)
        res.timings = timings
        res.resumed_from = start
        # install the journaled threshold schedule below the entry step;
        # re-estimations at steps >= start re-run deterministically in the
        # loop (their pending epochs died with the process)
        below = js.epochs_below(start)
        for s, thr_e, km in below:
            self.pipe.swap_thresholds(thr_e, s, kind_mult=km)
        res.reestimations = len(below)
        # journaled verdicts below the entry step are final; checks at
        # steps >= start recompute to bit-identical reports
        flagged_steps: list[int] = []
        for s in sorted(js.verdicts):
            if s >= start:
                continue
            rep = js.verdicts[s]
            res.checks[s] = rep
            if rep is not None:
                if not rep.passed:
                    flagged_steps.append(s)
                    self.ring.pin(s)
                if rep.loud:
                    res.loud_steps.append(s)
        res.losses = [float("nan")] * start
        res.cand_losses = [float("nan")] * start
        entry = (self._ref_state, self._cand_state)
        if start in self.keeper.steps:
            entry = self.keeper.load(start, self._ref_state,
                                     self._cand_state)
        if sc.journal:
            self.journal = Journal(journal_path(self.work_dir))
            self._j("resume", step=start, durable=list(self.keeper.steps))
        self.log(f"  [supervise] resuming at step {start} "
                 f"({len(res.checks)} journaled verdicts restored)")
        return self._run_loop(res, start=start,
                              flagged_steps=flagged_steps, entry=entry)

    def _save_ckpt(self, k: int, ref_state, cand_state) -> None:
        try:
            self.keeper.save(k, ref_state, cand_state)
        except Exception as e:        # noqa: BLE001 — surfaced + retried
            # an earlier enqueued save failed; the writer restarted, this
            # save re-submits — degraded checkpoint coverage is loud
            self.watchdog.event("loud", k, f"ckpt writer: {e}")
            self.keeper.save(k, ref_state, cand_state)

    def _ring_put(self, k: int, ref_tr, cand_tr) -> None:
        try:
            self.ring.put(k, ref_tr, cand_tr)
        except Exception as e:        # noqa: BLE001 — surfaced, not fatal
            # the put itself landed in memory before the stored writer
            # error surfaced; the worker restarts on the next eviction and
            # only spill coverage (not training) degraded
            self.watchdog.event("loud", k, f"spill writer: {e}")

    def _run_loop(self, res: SuperviseResult, start: int,
                  flagged_steps: list[int], entry) -> SuperviseResult:
        # the finally matters on the crash path: a loop that dies mid-run
        # (fault injection, a real bug) must still drain the journal's
        # write queue before an in-process resume() reads the file, and
        # must not leak the spill/ckpt worker threads of a finished run
        try:
            return self._run_loop_inner(res, start, flagged_steps, entry)
        finally:
            if self.journal is not None:
                self.journal.close()
            self.ring.stop()
            self.keeper.stop()

    def _run_loop_inner(self, res: SuperviseResult, start: int,
                        flagged_steps: list[int], entry) -> SuperviseResult:
        sc = self.scfg
        timings = res.timings
        (rp, rs), (cp, cs) = entry
        cand_step = self.candidate.step
        t_loop = time.perf_counter()
        t_warm = None          # set once compile-bearing first steps are done
        k = start
        # a resumed run whose journaled history already flagged goes
        # straight to diagnosis (the original run stopped there too)
        if not (flagged_steps and sc.stop_on_flag):
            for k in range(start, sc.steps):
                if self.fault is not None:
                    self.fault.step_start(k)       # crash fault fires here
                if k == start + 2:
                    for x in res.losses + res.cand_losses:
                        getattr(x, "block_until_ready", lambda: None)()
                    t_warm = time.perf_counter()
                if k % sc.ckpt_every == 0:
                    self._save_ckpt(k, (rp, rs), (cp, cs))
                batch = self.batch_fn(k)
                if (sc.reestimate_every and k
                        and k % sc.reestimate_every == 0):
                    self._reestimate(k, rp, rs, batch, res)
                # both steps dispatch back-to-back — no host barrier between
                # them; with a spare device the reference runs on its own
                # device set concurrently with the candidate, and the host
                # blocks only where the pipeline consumes values
                ref_tr, rp, rs = self._ref_step(rp, rs, batch)
                cand_tr, cp, cs = cand_step(cp, cs, batch)
                if self.fault is not None:
                    cand_tr = self.fault.cand_trace(k, cand_tr)
                res.losses.append(ref_tr.loss)
                res.cand_losses.append(cand_tr.loss)
                if (sc.check_every > 0 and sc.async_window > 0
                        and k % sc.check_every == 0):
                    # saturation probe feeds the degradation policy BEFORE
                    # the cadence decision: a sick pipeline raises the
                    # effective cadence (checking degrades to sampling)
                    # instead of blocking the loop on every submit
                    self.degrade.note(k, self.pipe.saturated)
                checked = False
                if (sc.check_every > 0
                        and k % self.degrade.effective_check_every == 0):
                    checked = True
                    if sc.async_window == 0:
                        done = [self.pipe.check_sync(k, ref_tr, cand_tr)]
                    else:
                        done = self.pipe.submit(k, ref_tr, cand_tr)
                else:
                    done = self.pipe.poll()
                self._j("step", step=k, checked=checked)
                self._ring_put(k, ref_tr, cand_tr)
                if (self._absorb(done, res, flagged_steps)
                        and sc.stop_on_flag):
                    k += 1
                    break
            else:
                k = sc.steps
        self._absorb(self.pipe.drain(), res, flagged_steps)
        try:
            self.ring.flush()        # background spill writes land on disk
        except Exception as e:        # noqa: BLE001 — coverage loss, loud
            self.watchdog.event("loud", k, f"spill writer: {e}")
        try:
            self.keeper.flush()      # checkpoint writes are durable too
        except Exception as e:        # noqa: BLE001 — coverage loss, loud
            self.watchdog.event("loud", k, f"ckpt writer: {e}")
        res.steps_run = k
        res.losses = [float(x) for x in res.losses]
        res.cand_losses = [float(x) for x in res.cand_losses]
        ran = max(res.steps_run - start, 0)
        timings["loop_s"] = time.perf_counter() - t_loop
        timings["steps_per_s"] = ran / max(timings["loop_s"], 1e-9)
        if t_warm is not None and ran > 2:
            # steady-state rate: first two steps carry jit compilation
            steady_s = time.perf_counter() - t_warm
            timings["steady_steps_per_s"] = (ran - 2) / max(steady_s, 1e-9)

        if flagged_steps:
            res.flagged = True
            res.first_flagged_step = min(flagged_steps)
            t0 = time.perf_counter()
            self._diagnose(res)
            timings["diagnose_s"] = time.perf_counter() - t0
        res.timings = timings
        res.checks_rescued = self.pipe.rescued
        res.checks_lost = self.pipe.lost
        res.watchdog_events = [str(e) for e in self.watchdog.events]
        res.degradations = [str(e) for e in self.degrade.events]
        res.degraded_check_every = (self.degrade.effective_check_every
                                    if self.degrade.degraded else None)
        self._j("end", steps_run=res.steps_run, flagged=res.flagged,
                first_bad_step=res.first_bad_step)
        if self.journal is not None:
            self.journal.close()
        return res

    def _absorb(self, done: list[StepCheck], res: SuperviseResult,
                flagged_steps: list[int]) -> bool:
        hit = False
        for chk in done:
            res.checks[chk.step] = chk.report
            self._j("verdict", step=chk.step,
                    report=report_to_payload(chk.report))
            rep = chk.report
            if rep is not None and rep.loud:
                if chk.step not in res.loud_steps:
                    res.loud_steps.append(chk.step)
                self._j("loud", step=chk.step,
                        tensors=[r.name for r in rep.loud])
                self.log(f"  [supervise] step {chk.step} LOUD failure "
                         f"({len(rep.loud)} non-finite tensors)")
            if chk.flagged:
                flagged_steps.append(chk.step)
                if not self.ring.pin(chk.step):
                    self.log(f"  [supervise] step {chk.step} trace already "
                             f"evicted before its check resolved — raise "
                             f"ring_window or enable spill")
                hit = True
                self.log(f"  [supervise] step {chk.step} FLAGGED "
                         f"({len(chk.report.flagged)} tensors, localized: "
                         f"{chk.report.localized})")
        return hit

    # ---- diagnosis: bisect + localize --------------------------------------
    def _params_diverged(self, ckpt_step: int) -> bool:
        # host-only probe: just the two param trees, no opt state, no
        # device placement — O(log C) of these run per bisection.  The
        # threshold schedule (epoch + drift growth) is the pipeline's, so
        # the probe agrees with the online checks of that step.
        try:
            rp, cp = self.keeper.load_params_named(ckpt_step)
        except (ChecksumError, FileNotFoundError) as e:
            # corrupt payload: discard the checkpoint and answer "diverged"
            # — the search retreats toward step 0, and ``good`` is only
            # ever set from checkpoints that actually probed clean
            self.watchdog.event("loud", ckpt_step,
                                f"corrupt checkpoint probe: {e}")
            self.keeper.discard(ckpt_step)
            return True
        errs = batched_rel_err(rp, cp)
        return any(e > self.pipe.param_post_threshold(n, ckpt_step)
                   for n, e in errs.items())

    def _replay(self, start: int, end: int):
        """Deterministic sync-checked replay; returns the first flagged
        StepCheck and stashes the entry states + reference trace of that
        step for localization.  A checkpoint that fails CRC at restore is
        discarded and the replay retreats to an earlier one (ultimately
        the in-memory initial states) — a longer replay, never a wrong
        verdict built on corrupt state."""
        while True:
            try:
                (rp, rs), (cp, cs) = self.keeper.load(start, self._ref_state,
                                                      self._cand_state)
                break
            except (ChecksumError, FileNotFoundError) as e:
                self.watchdog.event("loud", start,
                                    f"corrupt checkpoint at replay: {e}")
                self.keeper.discard(start)
                earlier = [s for s in self.keeper.steps if s < start]
                if not earlier:
                    # _ref_state/_cand_state hold the build-time initial
                    # states (they are only ever used as templates)
                    (rp, rs), (cp, cs) = self._ref_state, self._cand_state
                    start = 0
                    break
                start = max(earlier)
        cand_step = self.candidate.step
        self._bad_entry = None
        for k in range(start, end + 1):
            entry = ((rp, rs), (cp, cs))
            batch = self.batch_fn(k)
            ref_tr, rp, rs = self._ref_step(rp, rs, batch)
            cand_tr, cp, cs = cand_step(cp, cs, batch)
            if self.fault is not None:
                # an injected numeric fault is part of the run under
                # diagnosis: the replay must reproduce it, or bisection
                # would "lose" the verdict it is refining
                cand_tr = self.fault.cand_trace(k, cand_tr)
            chk = self.pipe.check_sync(k, ref_tr, cand_tr)
            if chk.flagged:
                self._bad_entry = (entry, ref_tr)
                return chk
        return None

    def _diagnose(self, res: SuperviseResult) -> None:
        sc = self.scfg
        try:
            self.keeper.flush()  # in-flight saves land before bisection
        except Exception as e:    # noqa: BLE001 — coverage loss, loud
            self.watchdog.event("loud", res.first_flagged_step or 0,
                                f"ckpt writer: {e}")
        res.bisection = bisect_first_bad(self.keeper.steps,
                                         res.first_flagged_step,
                                         self._params_diverged, self._replay)
        res.first_bad_step = res.bisection.first_bad_step
        res.bad_check = res.bisection.check
        self.ring.pin(res.first_bad_step)
        rep = res.bad_check.report if res.bad_check else None
        if (not sc.localize or rep is None
                or rep.localization_mode != "propagation"
                or getattr(self, "_bad_entry", None) is None):
            return
        # forward divergence: entry states still agree (this IS the first
        # bad step), so rewrite-mode module isolation applies as in the
        # single-step workflow (paper §3 step 5)
        ((rp, rs), (cp, cs)), ref_tr = self._bad_entry
        ref_runner = make_model_runner(self.model, rp, self.opt, rs)
        cand_runner = self.candidate.make_runner(cp, cs)
        res.localization = localize_with_rewrites(
            ref_runner, cand_runner, self.batch_fn(res.first_bad_step),
            ref_tr, self.pipe.thresholds_for(res.first_bad_step))
