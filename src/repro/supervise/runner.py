"""Multi-step supervisor: online TTrace over a whole training run.

``Supervisor`` threads (params, opt_state) through BOTH the single-device
reference and the distributed candidate for N steps, using exactly one
compiled step per side (``collector.make_trace_step`` / the recipe's
``CandidateStep`` — no re-tracing, no re-jitting per step), and checks
every step online through the async pipeline.  With ``overlap=True`` (the
default) every non-training cost rides off the critical path: the
reference step dispatches on a spare device concurrently with the
candidate, spill writes run on a background thread, and threshold
re-estimation resolves like an async check — all bit-identical to the
lockstep path (``overlap=False``), which exists for A/B timing and the
determinism tests:

    step k trains  ->  step-k reductions enqueue on device  ->  step k+1
    trains while step k's N x 2 scalars are still in flight  ->  the
    bounded window resolves step k's report

The candidate side is RECIPE-GENERIC: ``CandidateStep`` is the contract —
a once-compiled stateful train step plus a runner factory for rewrite-mode
localization and the recipe's machine epsilon — and ``CandidateStep.build``
dispatches on the ``ParallelConfig`` to the shard_map candidate (dense /
MoE / ZeRO-1), the pipeline-parallel candidate (``parallel.pp``) or the FP8
recipes (``precision.fp8``, checked under BF16 epsilon per paper §6.7).

With ``reestimate_every=R`` the supervised loop additionally re-runs the
fused pair-step threshold estimate on the live batch every R steps and
swaps the (union-merged) thresholds into the async pipeline — margins then
tighten from the coarse ``SUPERVISED_KIND_MULT`` constants to
``REESTIMATED_KIND_MULT``, back toward the paper's single-step 8x.

On a flag the run is bisected to the FIRST bad step (checkpoint binary
search + deterministic sync replay, ``supervise.bisect``) and that step is
handed to the paper's localization machinery — propagation/backward/
optimizer modes from the step report, plus rewrite-mode module isolation
when the divergence is in the forward pass.  This is the paper's §3
workflow (steps 1-5) run as a loop over the whole training run instead of
a single snapshot.
"""
from __future__ import annotations

import os
import tempfile
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

from repro.core import canonical as C
from repro.core.checker import Report, localize_with_rewrites
from repro.core.collector import make_trace_step
from repro.core.harness import make_model_runner
from repro.core.relerr_engine import batched_rel_err
from repro.core.thresholds import (MACHINE_EPS, Thresholds,
                                   estimate_thresholds, make_pair_estimator)
from repro.data.synthetic import make_batch
from repro.parallel.api import (ParallelConfig, make_candidate_runner,
                                make_candidate_train_step)
from repro.supervise.bisect import (BisectResult, CheckpointKeeper,
                                    bisect_first_bad)
from repro.supervise.pipeline import (REESTIMATED_KIND_MULT,
                                      AsyncCheckPipeline, StepCheck)
from repro.supervise.store import TraceRing


@dataclass
class CandidateStep:
    """The recipe-generic candidate contract the supervisor drives.

    ``step(params, opt_state, batch) -> (Trace, new_params, new_opt_state)``
    must be a ONCE-compiled stateful train step (same compiled callable
    every supervised step and bisection replay); ``make_runner(params,
    opt_state)`` builds the one-shot ``runner(batch, rewrites) -> Trace``
    used for rewrite-mode localization at the first bad step; ``eps`` is
    the machine epsilon threshold estimation should use for this recipe
    (BF16's for FP8 recipes, paper §6.7).
    """
    step: Callable
    params0: Any
    opt_state0: Any
    make_runner: Callable
    eps: float = MACHINE_EPS["float32"]
    name: str = "candidate"
    # widening of the supervised per-step kind margins this recipe's
    # numerics need on top of the reference estimate (param_post exempt):
    # the 1F1B engine accumulates M per-microbatch partial reductions, a
    # reassociation the single-batch estimate cannot see
    kind_scale: float = 1.0

    @classmethod
    def build(cls, cfg, pcfg: ParallelConfig, params, opt,
              batch) -> "CandidateStep":
        """Dispatch on ``pcfg`` (shard_map / pp / 1F1B / fp8) via
        ``parallel.api``."""
        import math
        step, p0, s0 = make_candidate_train_step(cfg, pcfg, params, opt,
                                                 batch)
        eps = (MACHINE_EPS["float8_e4m3fn"] if pcfg.fp8
               else MACHINE_EPS["float32"])
        kind_scale = 1.0
        if pcfg.recipe_kind == "pp_1f1b":
            name = f"pp1f1b{pcfg.pp}x{pcfg.microbatches}"
            kind_scale = max(2.0, math.sqrt(pcfg.microbatches))
        elif pcfg.fp8:
            name = "fp8-" + pcfg.fp8
        elif pcfg.pp > 1:
            name = f"pp{pcfg.pp}"
        else:
            name = "shard_map"
        return cls(
            step=step, params0=p0, opt_state0=s0,
            make_runner=lambda p, s: make_candidate_runner(
                cfg, pcfg, p, opt, s),
            eps=eps, name=name, kind_scale=kind_scale)


@dataclass
class SuperviseConfig:
    steps: int = 8
    check_every: int = 1        # online check every C-th step; 0 = never
    async_window: int = 2       # in-flight device checks; 0 = synchronous
    # overlap everything off the training critical path: reference step on
    # its own (spare) device set dispatched concurrently with the
    # candidate, background spill writes, threshold re-estimation resolved
    # like an async check.  False = the lockstep path (same results
    # bit-for-bit; the determinism tests pin that)
    overlap: bool = True
    ckpt_every: int = 4         # periodic bisection checkpoints
    ckpt_keep: int = 16         # checkpoint count bound (log-spaced thinning)
    ring_window: int = 4        # live trace pairs kept in memory
    spill: bool = True          # spill evicted trace pairs to disk
    spill_keep: int = 8         # unpinned spilled steps retained on disk
    drift_alpha: float = 0.125  # per-step threshold growth allowance
    reestimate_every: int = 0   # re-run the fused pair estimate every R steps
    eps: Optional[float] = None  # None = auto (recipe eps; BF16 for FP8)
    margin: float = 8.0
    localize: bool = True       # rewrite-mode localization at the bad step
    stop_on_flag: bool = True   # end the run once a resolved check flags
    work_dir: Optional[str] = None   # checkpoints + spill (tmp if None)
    seed: int = 0


@dataclass
class SuperviseResult:
    flagged: bool
    steps_run: int
    first_flagged_step: Optional[int]   # first ONLINE-checked step flagging
    first_bad_step: Optional[int]       # after bisection refinement
    checks: dict = field(default_factory=dict)   # step -> Report (resolved)
    bad_check: Optional[StepCheck] = None
    bisection: Optional[BisectResult] = None
    localization: Optional[Report] = None        # rewrite-mode report
    thresholds: Optional[Thresholds] = None
    reestimations: int = 0              # threshold epochs swapped in
    losses: list = field(default_factory=list)          # reference loss/step
    cand_losses: list = field(default_factory=list)
    timings: dict = field(default_factory=dict)
    work_dir: Optional[str] = None

    @property
    def passed(self) -> bool:
        return not self.flagged

    @property
    def localized_module(self) -> Optional[str]:
        if self.localization is not None and self.localization.localized:
            return self.localization.localized
        if self.bad_check is not None and self.bad_check.report is not None:
            return self.bad_check.report.localized
        if self.first_flagged_step is not None:
            return self.checks[self.first_flagged_step].localized
        return None

    def summary(self, max_rows: int = 8) -> str:
        lines = []
        status = "PASS" if self.passed else "FAIL"
        lines.append(f"supervised run: {status} over {self.steps_run} steps "
                     f"({len(self.checks)} checked online)")
        if self.reestimations:
            lines.append(f"  thresholds re-estimated {self.reestimations}x "
                         f"on live batches")
        if self.flagged:
            lines.append(f"  first flagged (online): step "
                         f"{self.first_flagged_step}")
            if self.bisection is not None:
                lines.append("  " + self.bisection.summary())
            lines.append(f"  FIRST BAD STEP: {self.first_bad_step}")
            if self.bad_check is not None and self.bad_check.report:
                rep = self.bad_check.report
                for ln in rep.summary(max_rows=max_rows).splitlines():
                    lines.append("  " + ln)
            if self.localization is not None and self.localization.localized:
                lines.append(f"  LOCALIZED (rewrite): bug in module "
                             f"'{self.localization.localized}'")
        return "\n".join(lines)


class Supervisor:
    """Streaming lockstep supervisor for one (model, recipe) pairing.

    ``batch_fn(step) -> batch`` defaults to the deterministic synthetic
    generator, which is also what makes bisection replay exact.  Pass
    ``candidate`` to drive a custom ``CandidateStep``; by default one is
    built from ``pcfg`` (shard_map / pp / fp8).
    """

    def __init__(self, model, cfg, pcfg: ParallelConfig, opt,
                 params=None, scfg: Optional[SuperviseConfig] = None,
                 batch_fn: Optional[Callable[[int], dict]] = None,
                 batch_size: int = 4, seq_len: int = 32,
                 candidate: Optional[CandidateStep] = None,
                 log_fn: Optional[Callable[[str], None]] = None):
        import jax
        self.model, self.cfg, self.pcfg, self.opt = model, cfg, pcfg, opt
        self.scfg = scfg or SuperviseConfig()
        self.params0 = (params if params is not None
                        else model.init(jax.random.PRNGKey(self.scfg.seed)))
        self.batch_fn = batch_fn or (
            lambda step: make_batch(cfg, batch_size, seq_len,
                                    seed=self.scfg.seed, step=step))
        self.log = log_fn or (lambda s: None)
        self.work_dir = (self.scfg.work_dir
                         or tempfile.mkdtemp(prefix="ttrace_supervise_"))
        self.keeper = CheckpointKeeper(os.path.join(self.work_dir, "ckpt"),
                                       keep=self.scfg.ckpt_keep)
        # a step's async check resolves at most async_window * check_every
        # puts after its own, and pinning happens at resolution — the ring
        # must still hold the step then, or flagged evidence is lost (the
        # "pinned steps are never dropped" contract).  check_every = 0 runs
        # no checks at all, so nothing constrains the ring (this used to
        # blow the window up to async_window * check_every and keep every
        # trace of the run live — the "checking off slower than checking
        # on" bench anomaly)
        if self.scfg.check_every > 0:
            min_window = min(self.scfg.async_window
                             * self.scfg.check_every + 1,
                             self.scfg.steps + 1)
        else:
            min_window = 1
        self.ring = TraceRing(
            window=max(self.scfg.ring_window, min_window),
            spill_dir=(os.path.join(self.work_dir, "spill")
                       if self.scfg.spill else None),
            spill_keep=self.scfg.spill_keep,
            background=self.scfg.overlap)
        self.candidate = candidate
        self.pipe: Optional[AsyncCheckPipeline] = None
        self._ref_step = None
        self._ref_state = self._cand_state = None
        self._estimator = None
        self._bad_entry = None

    # ---- build (thresholds + compiled steps) -------------------------------
    def _ref_device(self):
        """The spare device the reference step (and the live threshold
        estimator) runs on — the device partition of the overlapped loop.
        None (shared placement) when nothing is spare or overlap is off."""
        from repro.parallel.api import spare_host_device
        return spare_host_device(self.pcfg) if self.scfg.overlap else None

    def _build(self):
        sc = self.scfg
        batch0 = self.batch_fn(0)
        t0 = time.perf_counter()
        if self.candidate is None:
            self.candidate = CandidateStep.build(self.cfg, self.pcfg,
                                                 self.params0, self.opt,
                                                 batch0)
        eps = sc.eps if sc.eps is not None else self.candidate.eps
        self.eps = eps
        ref_runner = make_model_runner(self.model, self.params0, self.opt,
                                       self.opt.init(self.params0))
        thr, _ = estimate_thresholds(ref_runner, batch0, eps, sc.margin,
                                     sc.seed)
        t_thr = time.perf_counter() - t0
        # margins start at the constant widening either way: until the first
        # live re-estimation lands, only the step-0 estimate exists and the
        # full batch-to-batch allowance is still needed
        self.pipe = AsyncCheckPipeline(thr, window=sc.async_window,
                                       drift_alpha=sc.drift_alpha,
                                       kind_scale=self.candidate.kind_scale)

        def loss_call(p, b, ctx):
            return self.model.loss(p, b, ctx=ctx)[0]

        t0 = time.perf_counter()
        ref_dev = self._ref_device()
        self._ref_step = make_trace_step(loss_call, self.opt, self.params0,
                                         batch0, device=ref_dev)
        self._ref_state = (self.params0, self.opt.init(self.params0))
        self._cand_state = (self.candidate.params0,
                            self.candidate.opt_state0)
        timings = {"thresholds_s": t_thr}
        if sc.reestimate_every:
            self._estimator = make_pair_estimator(
                loss_call, self.opt, self.params0, batch0, eps, sc.margin,
                sc.seed, device=ref_dev)
            # compile (and discard) one estimate now: the first live epoch
            # would otherwise carry seconds of jit time INSIDE the steady
            # loop — the dominant share of the old reest_async2 overhead
            t1 = time.perf_counter()
            self._estimator(self._ref_state[0], self._ref_state[1], batch0)
            timings["estimator_warmup_s"] = time.perf_counter() - t1
        timings["build_s"] = time.perf_counter() - t0
        return thr, timings

    # ---- periodic threshold re-estimation ----------------------------------
    def _reestimate(self, k: int, rp, rs, batch, res: SuperviseResult):
        """Dispatch the live-batch pair estimate and register it as a
        PENDING threshold epoch: the device computation overlaps the
        training steps behind it, and the pipeline resolves it the moment a
        check at step >= k needs the epoch (or opportunistically once the
        reduction is ready) — bit-identical thresholds to the synchronous
        stall, none of the stall.  From the first live estimate on, the
        union tracks the real noise level and the constant widening
        tightens to the re-estimated multipliers (steps before this keep
        SUPERVISED_KIND_MULT)."""
        t0 = time.perf_counter()
        resolve = self._estimator.submit(rp, rs, batch, step=k)
        self.pipe.schedule_epoch(k, resolve,
                                 kind_mult=REESTIMATED_KIND_MULT)
        if not self.scfg.overlap:
            self.pipe.settle_epochs(k)       # the lockstep path blocks here
        res.reestimations += 1
        res.timings["reestimate_s"] = (res.timings.get("reestimate_s", 0.0)
                                       + time.perf_counter() - t0)
        self.log(f"  [supervise] step {k}: live-batch threshold estimate "
                 f"dispatched (epoch {res.reestimations})")

    # ---- main loop ---------------------------------------------------------
    def run(self) -> SuperviseResult:
        sc = self.scfg
        thr, timings = self._build()
        res = SuperviseResult(flagged=False, steps_run=0,
                              first_flagged_step=None, first_bad_step=None,
                              thresholds=thr, work_dir=self.work_dir)
        res.timings = timings
        rp, rs = self._ref_state
        cp, cs = self._cand_state
        cand_step = self.candidate.step
        flagged_steps: list[int] = []
        t_loop = time.perf_counter()
        t_warm = None          # set once compile-bearing first steps are done
        k = 0
        for k in range(sc.steps):
            if k == 2:
                for x in res.losses + res.cand_losses:
                    getattr(x, "block_until_ready", lambda: None)()
                t_warm = time.perf_counter()
            if k % sc.ckpt_every == 0:
                self.keeper.save(k, (rp, rs), (cp, cs))
            batch = self.batch_fn(k)
            if (sc.reestimate_every and k
                    and k % sc.reestimate_every == 0):
                self._reestimate(k, rp, rs, batch, res)
            # both steps dispatch back-to-back — no host barrier between
            # them; with a spare device the reference runs on its own
            # device set concurrently with the candidate, and the host
            # blocks only where the pipeline consumes values
            ref_tr, rp, rs = self._ref_step(rp, rs, batch)
            cand_tr, cp, cs = cand_step(cp, cs, batch)
            res.losses.append(ref_tr.loss)
            res.cand_losses.append(cand_tr.loss)
            if sc.check_every > 0 and k % sc.check_every == 0:
                if sc.async_window == 0:
                    done = [self.pipe.check_sync(k, ref_tr, cand_tr)]
                else:
                    done = self.pipe.submit(k, ref_tr, cand_tr)
            else:
                done = self.pipe.poll()
            self.ring.put(k, ref_tr, cand_tr)
            if self._absorb(done, res, flagged_steps) and sc.stop_on_flag:
                k += 1
                break
        else:
            k = sc.steps
        self._absorb(self.pipe.drain(), res, flagged_steps)
        self.ring.flush()            # background spill writes land on disk
        res.steps_run = k
        res.losses = [float(x) for x in res.losses]
        res.cand_losses = [float(x) for x in res.cand_losses]
        timings["loop_s"] = time.perf_counter() - t_loop
        timings["steps_per_s"] = res.steps_run / max(timings["loop_s"], 1e-9)
        if t_warm is not None and res.steps_run > 2:
            # steady-state rate: first two steps carry jit compilation
            steady_s = time.perf_counter() - t_warm
            timings["steady_steps_per_s"] = ((res.steps_run - 2)
                                             / max(steady_s, 1e-9))

        if flagged_steps:
            res.flagged = True
            res.first_flagged_step = min(flagged_steps)
            t0 = time.perf_counter()
            self._diagnose(res)
            timings["diagnose_s"] = time.perf_counter() - t0
        res.timings = timings
        return res

    def _absorb(self, done: list[StepCheck], res: SuperviseResult,
                flagged_steps: list[int]) -> bool:
        hit = False
        for chk in done:
            res.checks[chk.step] = chk.report
            if chk.flagged:
                flagged_steps.append(chk.step)
                if not self.ring.pin(chk.step):
                    self.log(f"  [supervise] step {chk.step} trace already "
                             f"evicted before its check resolved — raise "
                             f"ring_window or enable spill")
                hit = True
                self.log(f"  [supervise] step {chk.step} FLAGGED "
                         f"({len(chk.report.flagged)} tensors, localized: "
                         f"{chk.report.localized})")
        return hit

    # ---- diagnosis: bisect + localize --------------------------------------
    def _params_diverged(self, ckpt_step: int) -> bool:
        # host-only probe: just the two param trees, no opt state, no
        # device placement — O(log C) of these run per bisection.  The
        # threshold schedule (epoch + drift growth) is the pipeline's, so
        # the probe agrees with the online checks of that step.
        rp, cp = self.keeper.load_params_named(ckpt_step)
        errs = batched_rel_err(rp, cp)
        return any(e > self.pipe.param_post_threshold(n, ckpt_step)
                   for n, e in errs.items())

    def _replay(self, start: int, end: int):
        """Deterministic sync-checked replay; returns the first flagged
        StepCheck and stashes the entry states + reference trace of that
        step for localization."""
        (rp, rs), (cp, cs) = self.keeper.load(start, self._ref_state,
                                              self._cand_state)
        cand_step = self.candidate.step
        self._bad_entry = None
        for k in range(start, end + 1):
            entry = ((rp, rs), (cp, cs))
            batch = self.batch_fn(k)
            ref_tr, rp, rs = self._ref_step(rp, rs, batch)
            cand_tr, cp, cs = cand_step(cp, cs, batch)
            chk = self.pipe.check_sync(k, ref_tr, cand_tr)
            if chk.flagged:
                self._bad_entry = (entry, ref_tr)
                return chk
        return None

    def _diagnose(self, res: SuperviseResult) -> None:
        sc = self.scfg
        res.bisection = bisect_first_bad(self.keeper.steps,
                                         res.first_flagged_step,
                                         self._params_diverged, self._replay)
        res.first_bad_step = res.bisection.first_bad_step
        res.bad_check = res.bisection.check
        self.ring.pin(res.first_bad_step)
        rep = res.bad_check.report if res.bad_check else None
        if (not sc.localize or rep is None
                or rep.localization_mode != "propagation"
                or getattr(self, "_bad_entry", None) is None):
            return
        # forward divergence: entry states still agree (this IS the first
        # bad step), so rewrite-mode module isolation applies as in the
        # single-step workflow (paper §3 step 5)
        ((rp, rs), (cp, cs)), ref_tr = self._bad_entry
        ref_runner = make_model_runner(self.model, rp, self.opt, rs)
        cand_runner = self.candidate.make_runner(cp, cs)
        res.localization = localize_with_rewrites(
            ref_runner, cand_runner, self.batch_fn(res.first_bad_step),
            ref_tr, self.pipe.thresholds_for(res.first_bad_step))
