"""Trace ring buffer with spill-to-disk eviction for supervised runs.

A supervised run produces TWO full traces per step; keeping them all alive
would grow device memory linearly with run length.  ``TraceRing`` keeps the
last ``window`` steps live (device-resident, instantly available for
diagnosis when an async check resolves against them) and evicts older steps:

* with a ``spill_dir``, evicted steps are written to disk in the SAME
  sharded-npz + JSON-manifest format as ``repro.checkpoint.store`` (one
  directory per step, one manifest per side), and the on-disk set is itself
  a ring of ``spill_keep`` steps;
* without one, evicted steps are dropped.

``pin(step)`` marks a step as evidence (the supervisor pins every flagged
step): pinned steps are never dropped — they are spilled on eviction even
when unpinned spill is bounded, and never pruned from disk — so the full
trace of every suspicious step survives an arbitrarily long run while
memory and disk stay flat.

With ``background=True`` the spill write itself (device->host transfer +
npz serialization — the ONLY blocking work in the supervised hot loop)
moves to a worker thread behind a bounded queue: eviction enqueues and
returns, the writer drains while training dispatches ahead.  The queue
bound is the backpressure (at most ``queue_max`` evicted pairs buffered
beyond the ring), pins win every race with eviction (a step is pinnable
while in memory, queued, or on disk — never silently lost in between),
and ``flush()`` joins the queue (re-raising any writer error) so diagnosis
and end-of-run introspection see a complete disk state.
"""
from __future__ import annotations

import os
import queue
import shutil
import threading
from collections import OrderedDict

import numpy as np

from repro.checkpoint.store import (load_checkpoint_named, save_checkpoint)
from repro.core.collector import _SECTION_FIELDS, Trace


def save_trace(path: str, tr: Trace, *, step: int = 0) -> None:
    """Spill one trace as a manifest checkpoint (raw binary shards: same
    manifest/loader as the npz checkpoints, ~3x less serialization CPU —
    the spill writer shares cores with training)."""
    tree = {f: {name: np.asarray(leaf)
                for name, leaf in getattr(tr, f).raw_items()}
            for f in _SECTION_FIELDS}
    extra = {"loss": float(tr.loss), "grad_norm": float(tr.grad_norm),
             "fwd_order": list(tr.meta.get("fwd_order", []))}
    save_checkpoint(path, tree, step=step, extra=extra, container="raw")


def load_trace(path: str) -> Trace:
    """Reload a spilled trace (sections come back as host numpy)."""
    named, _, extra = load_checkpoint_named(path)
    tr = Trace()
    sections: dict[str, dict] = {f: {} for f in _SECTION_FIELDS}
    for key, arr in named.items():
        field, _, name = key.partition(".")
        sections[field][name] = arr
    for f in _SECTION_FIELDS:
        setattr(tr, f, sections[f])
    tr.loss = extra.get("loss", float("nan"))
    tr.grad_norm = extra.get("grad_norm", float("nan"))
    if extra.get("fwd_order"):
        tr.meta["fwd_order"] = list(extra["fwd_order"])
    return tr


class TraceRing:
    """Bounded ring of per-step (reference, candidate) trace pairs.

    ``background=True`` moves spill writes onto a worker thread behind a
    bounded queue (``queue_max`` evicted pairs); ``flush()`` blocks until
    the queue drains.  All bookkeeping is lock-protected, so pins race
    safely against eviction and the writer.
    """

    def __init__(self, window: int = 4, spill_dir: str | None = None,
                 spill_keep: int = 8, background: bool = False,
                 queue_max: int = 4):
        self.window = max(1, int(window))
        self.spill_dir = spill_dir
        self.spill_keep = max(0, int(spill_keep))
        self._mem: OrderedDict[int, tuple[Trace, Trace]] = OrderedDict()
        self._queued: OrderedDict[int, tuple[Trace, Trace]] = OrderedDict()
        self._spilled: OrderedDict[int, str] = OrderedDict()
        self._pinned: set[int] = set()
        self._lock = threading.Lock()
        self._queue: queue.Queue | None = None
        self._writer: threading.Thread | None = None
        self._writer_error: BaseException | None = None
        self.background = bool(background) and spill_dir is not None
        self.queue_max = max(1, int(queue_max))
        self.spill_count = 0
        self.drop_count = 0

    # ---- introspection -----------------------------------------------------
    @property
    def in_memory(self) -> list[int]:
        with self._lock:
            return list(self._mem)

    @property
    def on_disk(self) -> list[int]:
        with self._lock:
            return list(self._spilled)

    @property
    def pinned(self) -> set[int]:
        with self._lock:
            return set(self._pinned)

    def __contains__(self, step: int) -> bool:
        with self._lock:
            return (step in self._mem or step in self._queued
                    or step in self._spilled)

    # ---- ring --------------------------------------------------------------
    def put(self, step: int, ref: Trace, cand: Trace) -> None:
        self._mem[step] = (ref, cand)
        self._evict()

    def pin(self, step: int) -> bool:
        """Mark a step as evidence (never dropped).  False if the step was
        already evicted without spill — nothing left to preserve.  The pin
        wins races with eviction: a step still in memory, in the writer
        queue, or on disk is preserved wherever it currently lives."""
        with self._lock:
            if (step not in self._mem and step not in self._queued
                    and step not in self._spilled):
                return False
            self._pinned.add(step)
            return True

    def get(self, step: int) -> tuple[Trace, Trace]:
        with self._lock:
            if step in self._mem:
                return self._mem[step]
            if step in self._queued:        # evicted, write still pending
                return self._queued[step]
            root = self._spilled.get(step)
        if root is not None:
            try:
                return (load_trace(os.path.join(root, "ref")),
                        load_trace(os.path.join(root, "cand")))
            except FileNotFoundError:
                # lost the race with the writer's disk pruning of an
                # unpinned step — same verdict as never having kept it
                pass
        raise KeyError(f"step {step} not retained (window={self.window}, "
                       f"spill={'on' if self.spill_dir else 'off'})")

    def flush(self) -> None:
        """Block until every queued spill write has landed on disk (no-op
        without a background writer); re-raises a failed writer's error."""
        if self._queue is not None:
            self._queue.join()
        if self._writer_error is not None:
            err, self._writer_error = self._writer_error, None
            raise err

    def _evict(self) -> None:
        if self.spill_dir is not None:
            # memory stays flat: everything past the window spills, pinned
            # included (the disk copy is the durable one)
            while len(self._mem) > self.window:
                step, (ref, cand) = self._mem.popitem(last=False)
                if self.background:
                    self._enqueue(step, ref, cand)
                else:
                    self._spill(step, ref, cand)
                    self._prune_disk()
        else:
            # no spill backing: pinned evidence stays live and does not
            # count against the window; oldest unpinned steps drop
            with self._lock:
                unpinned = [s for s in self._mem if s not in self._pinned]
                while len(unpinned) > self.window:
                    del self._mem[unpinned.pop(0)]
                    self.drop_count += 1

    # ---- background writer -------------------------------------------------
    def _enqueue(self, step: int, ref: Trace, cand: Trace) -> None:
        if self._queue is None:
            self._queue = queue.Queue(maxsize=self.queue_max)
            self._writer = threading.Thread(target=self._write_loop,
                                            name="trace-spill-writer",
                                            daemon=True)
            self._writer.start()
        with self._lock:
            self._queued[step] = (ref, cand)
        # bounded queue: when the writer falls behind, this blocks — the
        # explicit backpressure that keeps evicted-but-unwritten traces
        # O(queue_max) instead of unbounded
        self._queue.put(step)

    def _write_loop(self) -> None:
        while True:
            step = self._queue.get()
            try:
                with self._lock:
                    pair = self._queued.get(step)
                if pair is not None:
                    self._spill(step, *pair)
                    with self._lock:
                        self._queued.pop(step, None)
                    self._prune_disk()
            except BaseException as e:
                # drop the unwritable pair (memory must stay flat even
                # when the disk is sick) and keep the FIRST error for the
                # next flush() — later failures usually echo the same
                # root cause
                with self._lock:
                    self._queued.pop(step, None)
                    self.drop_count += 1
                if self._writer_error is None:
                    self._writer_error = e
            finally:
                self._queue.task_done()

    def _spill(self, step: int, ref: Trace, cand: Trace) -> None:
        root = os.path.join(self.spill_dir, f"step_{step:06d}")
        save_trace(os.path.join(root, "ref"), ref, step=step)
        save_trace(os.path.join(root, "cand"), cand, step=step)
        with self._lock:
            self._spilled[step] = root
            self.spill_count += 1

    def _prune_disk(self) -> None:
        if self.spill_dir is None:
            return
        with self._lock:
            unpinned = [s for s in self._spilled if s not in self._pinned]
            doomed = []
            while len(unpinned) > self.spill_keep:
                s = unpinned.pop(0)
                doomed.append(self._spilled.pop(s))
        for root in doomed:
            shutil.rmtree(root, ignore_errors=True)
