"""Trace ring buffer with spill-to-disk eviction for supervised runs.

A supervised run produces TWO full traces per step; keeping them all alive
would grow device memory linearly with run length.  ``TraceRing`` keeps the
last ``window`` steps live (device-resident, instantly available for
diagnosis when an async check resolves against them) and evicts older steps:

* with a ``spill_dir``, evicted steps are written to disk in the SAME
  sharded + JSON-manifest format as ``repro.checkpoint.store`` (one
  directory per step, one manifest per side, CRC32 per piece so a rotted
  payload is rejected at load instead of silently feeding garbage into
  diagnosis), and the on-disk set is itself a ring of ``spill_keep`` steps;
* without one, evicted steps are dropped.

``pin(step)`` marks a step as evidence (the supervisor pins every flagged
step): pinned steps are never dropped — they are spilled on eviction even
when unpinned spill is bounded, and never pruned from disk — so the full
trace of every suspicious step survives an arbitrarily long run while
memory and disk stay flat.

With ``background=True`` the spill write itself (device->host transfer +
serialization — the ONLY blocking work in the supervised hot loop) moves
to a ``BackgroundWriter``: a worker thread behind a bounded queue shared
in design with the checkpoint keeper's writer.  Eviction enqueues and
returns, the writer drains while training dispatches ahead.  The queue
bound is the backpressure (at most ``queue_max`` evicted pairs buffered
beyond the ring), pins win every race with eviction (a step is pinnable
while in memory, queued, or on disk — never silently lost in between).
A writer failure — including the worker thread itself dying — surfaces on
the NEXT ``put()``/``get()`` (and at ``flush()``), after which the worker
is restarted: a sick disk degrades spill coverage loudly, it does not
silently rot until end-of-run.
"""
from __future__ import annotations

import os
import queue
import shutil
import threading
from collections import OrderedDict
from typing import Callable, Optional

import numpy as np

from repro.checkpoint.store import (ChecksumError, load_checkpoint_named,
                                    save_checkpoint)
from repro.core.collector import _SECTION_FIELDS, Trace


def save_trace(path: str, tr: Trace, *, step: int = 0) -> None:
    """Spill one trace as a manifest checkpoint (raw binary shards: same
    manifest/loader as the npz checkpoints, ~3x less serialization CPU —
    the spill writer shares cores with training)."""
    tree = {f: {name: np.asarray(leaf)
                for name, leaf in getattr(tr, f).raw_items()}
            for f in _SECTION_FIELDS}
    extra = {"loss": float(tr.loss), "grad_norm": float(tr.grad_norm),
             "fwd_order": list(tr.meta.get("fwd_order", []))}
    save_checkpoint(path, tree, step=step, extra=extra, container="raw")


def load_trace(path: str) -> Trace:
    """Reload a spilled trace (sections come back as host numpy).

    Raises ``ChecksumError`` when the payload fails CRC verification."""
    named, _, extra = load_checkpoint_named(path)
    tr = Trace()
    sections: dict[str, dict] = {f: {} for f in _SECTION_FIELDS}
    for key, arr in named.items():
        field, _, name = key.partition(".")
        sections[field][name] = arr
    for f in _SECTION_FIELDS:
        setattr(tr, f, sections[f])
    tr.loss = extra.get("loss", float("nan"))
    tr.grad_norm = extra.get("grad_norm", float("nan"))
    if extra.get("fwd_order"):
        tr.meta["fwd_order"] = list(extra["fwd_order"])
    return tr


class WriterDeath(RuntimeError):
    """Raised inside a background write to kill the worker thread itself
    (the ``dead_spill_writer`` fault) — distinct from a failing write,
    which the worker survives."""


class BackgroundWriter:
    """Bounded-queue single-thread background writer with loud failure.

    ``submit(fn)`` enqueues a write closure (blocking when ``queue_max``
    writes are already pending — the backpressure bound).  The FIRST
    error any write raises is stored and re-raised by ``take_error()`` /
    ``flush()``; a ``WriterDeath`` additionally terminates the worker
    thread, which ``ensure()`` transparently restarts after the error has
    been surfaced.  Used by the trace ring's spill path and the
    checkpoint keeper's save path.
    """

    _STOP = object()

    def __init__(self, name: str, queue_max: int = 4):
        self.name = name
        self.queue_max = max(1, int(queue_max))
        self._queue: Optional[queue.Queue] = None
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None
        self.failed_writes = 0

    @property
    def alive(self) -> bool:
        return self._thread is not None and self._thread.is_alive()

    def ensure(self) -> None:
        if self._queue is None:
            self._queue = queue.Queue(maxsize=self.queue_max)
        if not self.alive:
            self._thread = threading.Thread(target=self._loop,
                                            name=self.name, daemon=True)
            self._thread.start()

    def submit(self, fn: Callable[[], None]) -> None:
        self.ensure()
        self._queue.put(fn)

    def take_error(self) -> Optional[BaseException]:
        """Pop the stored writer error (None when healthy).  The caller
        re-raises it; the next ``submit`` restarts a dead worker."""
        err, self._error = self._error, None
        return err

    def flush(self) -> None:
        """Block until every queued write ran; re-raise a writer error.

        A DEAD worker cannot drain its queue — join would deadlock — so
        death is surfaced immediately instead, the queue is discarded
        (those writes are lost, which is exactly what the stored error
        reports), and the next submit starts fresh."""
        if self._queue is not None:
            if self.alive:
                self._queue.join()
            elif self._thread is not None:
                # dead worker: abandon undone writes so flush cannot hang
                try:
                    while True:
                        self._queue.get_nowait()
                        self._queue.task_done()
                except queue.Empty:
                    pass
        err = self.take_error()
        if err is not None:
            raise err

    def stop(self) -> None:
        """Drain queued writes and end the worker thread.  Restartable:
        the next ``submit``'s ``ensure()`` spawns a fresh worker, so
        post-run diagnosis (replay, rescan) keeps working — ``stop`` just
        keeps finished runs from leaking an idle thread apiece."""
        if self._queue is not None and self.alive:
            self._queue.put(BackgroundWriter._STOP)
            self._thread.join(timeout=10.0)

    def _loop(self) -> None:
        q = self._queue
        while True:
            fn = q.get()
            if fn is BackgroundWriter._STOP:
                q.task_done()
                return
            try:
                fn()
            except WriterDeath as e:
                if self._error is None:
                    self._error = e
                self.failed_writes += 1
                q.task_done()
                return                      # the worker thread dies
            except BaseException as e:      # noqa: BLE001 — surfaced later
                if self._error is None:
                    self._error = e
                self.failed_writes += 1
                q.task_done()
            else:
                q.task_done()


class TraceRing:
    """Bounded ring of per-step (reference, candidate) trace pairs.

    ``background=True`` moves spill writes onto a ``BackgroundWriter``;
    ``flush()`` blocks until the queue drains.  All bookkeeping is
    lock-protected, so pins race safely against eviction and the writer.
    A failed or dead writer surfaces its error on the next ``put()`` /
    ``get()`` / ``flush()`` and is restarted afterwards.

    ``fault_hook(step)`` (set by the fault-injection harness) may return
    an exception to raise inside the spill write of that step;
    ``on_spill(step, root)`` fires after a spill lands (the supervisor
    journals spill manifests and the harness corrupts payloads there).
    """

    def __init__(self, window: int = 4, spill_dir: str | None = None,
                 spill_keep: int = 8, background: bool = False,
                 queue_max: int = 4):
        self.window = max(1, int(window))
        self.spill_dir = spill_dir
        self.spill_keep = max(0, int(spill_keep))
        self._mem: OrderedDict[int, tuple[Trace, Trace]] = OrderedDict()
        self._queued: OrderedDict[int, tuple[Trace, Trace]] = OrderedDict()
        self._spilled: OrderedDict[int, str] = OrderedDict()
        self._pinned: set[int] = set()
        self._lock = threading.Lock()
        self.background = bool(background) and spill_dir is not None
        self._writer = (BackgroundWriter("trace-spill-writer",
                                         queue_max=queue_max)
                        if self.background else None)
        self.queue_max = max(1, int(queue_max))
        self.spill_count = 0
        self.drop_count = 0
        self.corrupt_count = 0
        self.fault_hook: Optional[Callable[[int],
                                           Optional[BaseException]]] = None
        self.on_spill: Optional[Callable[[int, str], None]] = None

    # ---- introspection -----------------------------------------------------
    @property
    def in_memory(self) -> list[int]:
        with self._lock:
            return list(self._mem)

    @property
    def on_disk(self) -> list[int]:
        with self._lock:
            return list(self._spilled)

    @property
    def pinned(self) -> set[int]:
        with self._lock:
            return set(self._pinned)

    def __contains__(self, step: int) -> bool:
        with self._lock:
            return (step in self._mem or step in self._queued
                    or step in self._spilled)

    # ---- ring --------------------------------------------------------------
    def _surface_writer_error(self) -> None:
        """Re-raise a stored writer error (the dead-writer contract: the
        error lands on the NEXT ring operation, not only at flush).  The
        worker restarts on the next enqueue."""
        if self._writer is not None:
            err = self._writer.take_error()
            if err is not None:
                raise err

    def put(self, step: int, ref: Trace, cand: Trace) -> None:
        self._mem[step] = (ref, cand)
        self._evict()
        self._surface_writer_error()

    def pin(self, step: int) -> bool:
        """Mark a step as evidence (never dropped).  False if the step was
        already evicted without spill — nothing left to preserve.  The pin
        wins races with eviction: a step still in memory, in the writer
        queue, or on disk is preserved wherever it currently lives."""
        with self._lock:
            if (step not in self._mem and step not in self._queued
                    and step not in self._spilled):
                return False
            self._pinned.add(step)
            return True

    def get(self, step: int) -> tuple[Trace, Trace]:
        self._surface_writer_error()
        with self._lock:
            if step in self._mem:
                return self._mem[step]
            if step in self._queued:        # evicted, write still pending
                return self._queued[step]
            root = self._spilled.get(step)
        if root is not None:
            try:
                return (load_trace(os.path.join(root, "ref")),
                        load_trace(os.path.join(root, "cand")))
            except FileNotFoundError:
                # lost the race with the writer's disk pruning of an
                # unpinned step — same verdict as never having kept it
                pass
            except ChecksumError as e:
                # detected at load, reported as lost evidence — never
                # silently fed into diagnosis
                self.corrupt_count += 1
                raise KeyError(f"step {step} spill payload corrupt: {e}")
        raise KeyError(f"step {step} not retained (window={self.window}, "
                       f"spill={'on' if self.spill_dir else 'off'})")

    def flush(self) -> None:
        """Block until every queued spill write has landed on disk (no-op
        without a background writer); re-raises a failed writer's error."""
        if self._writer is not None:
            self._writer.flush()

    def stop(self) -> None:
        """End the spill worker thread (drains first; restarts on the
        next ``put``) — end-of-run teardown, not a terminal state."""
        if self._writer is not None:
            self._writer.stop()

    def rescan(self) -> list[int]:
        """Rebuild the on-disk index from ``spill_dir`` (resume path: a
        previous incarnation's spills become addressable again).  Only
        steps with both side manifests present are indexed."""
        if self.spill_dir is None or not os.path.isdir(self.spill_dir):
            return []
        found = []
        for d in sorted(os.listdir(self.spill_dir)):
            if not d.startswith("step_"):
                continue
            root = os.path.join(self.spill_dir, d)
            if all(os.path.exists(os.path.join(root, side, "manifest.json"))
                   for side in ("ref", "cand")):
                found.append((int(d[len("step_"):]), root))
        with self._lock:
            for step, root in found:
                self._spilled.setdefault(step, root)
            self._spilled = OrderedDict(sorted(self._spilled.items()))
        return [s for s, _ in found]

    def _evict(self) -> None:
        if self.spill_dir is not None:
            # memory stays flat: everything past the window spills, pinned
            # included (the disk copy is the durable one)
            while len(self._mem) > self.window:
                step, (ref, cand) = self._mem.popitem(last=False)
                if self.background:
                    self._enqueue(step, ref, cand)
                else:
                    self._spill(step, ref, cand)
                    self._prune_disk()
        else:
            # no spill backing: pinned evidence stays live and does not
            # count against the window; oldest unpinned steps drop
            with self._lock:
                unpinned = [s for s in self._mem if s not in self._pinned]
                while len(unpinned) > self.window:
                    del self._mem[unpinned.pop(0)]
                    self.drop_count += 1

    # ---- background writer -------------------------------------------------
    def _enqueue(self, step: int, ref: Trace, cand: Trace) -> None:
        with self._lock:
            self._queued[step] = (ref, cand)
        # bounded queue: when the writer falls behind, this blocks — the
        # explicit backpressure that keeps evicted-but-unwritten traces
        # O(queue_max) instead of unbounded
        self._writer.submit(lambda: self._write_queued(step))

    def _write_queued(self, step: int) -> None:
        try:
            with self._lock:
                pair = self._queued.get(step)
            if pair is not None:
                self._spill(step, *pair)
                with self._lock:
                    self._queued.pop(step, None)
                self._prune_disk()
        except BaseException:
            # drop the unwritable pair (memory must stay flat even when
            # the disk is sick); the writer stores the error for the next
            # ring operation to surface
            with self._lock:
                self._queued.pop(step, None)
                self.drop_count += 1
            raise

    def _spill(self, step: int, ref: Trace, cand: Trace) -> None:
        if self.fault_hook is not None:
            err = self.fault_hook(step)
            if err is not None:
                raise err
        root = os.path.join(self.spill_dir, f"step_{step:06d}")
        save_trace(os.path.join(root, "ref"), ref, step=step)
        save_trace(os.path.join(root, "cand"), cand, step=step)
        with self._lock:
            self._spilled[step] = root
            self.spill_count += 1
        if self.on_spill is not None:
            self.on_spill(step, root)

    def _prune_disk(self) -> None:
        if self.spill_dir is None:
            return
        with self._lock:
            unpinned = [s for s in self._spilled if s not in self._pinned]
            doomed = []
            while len(unpinned) > self.spill_keep:
                s = unpinned.pop(0)
                doomed.append(self._spilled.pop(s))
        for root in doomed:
            shutil.rmtree(root, ignore_errors=True)
