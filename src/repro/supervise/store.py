"""Trace ring buffer with spill-to-disk eviction for supervised runs.

A supervised run produces TWO full traces per step; keeping them all alive
would grow device memory linearly with run length.  ``TraceRing`` keeps the
last ``window`` steps live (device-resident, instantly available for
diagnosis when an async check resolves against them) and evicts older steps:

* with a ``spill_dir``, evicted steps are written to disk in the SAME
  sharded-npz + JSON-manifest format as ``repro.checkpoint.store`` (one
  directory per step, one manifest per side), and the on-disk set is itself
  a ring of ``spill_keep`` steps;
* without one, evicted steps are dropped.

``pin(step)`` marks a step as evidence (the supervisor pins every flagged
step): pinned steps are never dropped — they are spilled on eviction even
when unpinned spill is bounded, and never pruned from disk — so the full
trace of every suspicious step survives an arbitrarily long run while
memory and disk stay flat.
"""
from __future__ import annotations

import os
import shutil
from collections import OrderedDict

import numpy as np

from repro.checkpoint.store import (load_checkpoint_named, save_checkpoint)
from repro.core.collector import _SECTION_FIELDS, Trace


def save_trace(path: str, tr: Trace, *, step: int = 0) -> None:
    """Spill one trace as a sharded-npz manifest checkpoint."""
    tree = {f: {name: np.asarray(leaf)
                for name, leaf in getattr(tr, f).raw_items()}
            for f in _SECTION_FIELDS}
    extra = {"loss": float(tr.loss), "grad_norm": float(tr.grad_norm),
             "fwd_order": list(tr.meta.get("fwd_order", []))}
    save_checkpoint(path, tree, step=step, extra=extra)


def load_trace(path: str) -> Trace:
    """Reload a spilled trace (sections come back as host numpy)."""
    named, _, extra = load_checkpoint_named(path)
    tr = Trace()
    sections: dict[str, dict] = {f: {} for f in _SECTION_FIELDS}
    for key, arr in named.items():
        field, _, name = key.partition(".")
        sections[field][name] = arr
    for f in _SECTION_FIELDS:
        setattr(tr, f, sections[f])
    tr.loss = extra.get("loss", float("nan"))
    tr.grad_norm = extra.get("grad_norm", float("nan"))
    if extra.get("fwd_order"):
        tr.meta["fwd_order"] = list(extra["fwd_order"])
    return tr


class TraceRing:
    """Bounded ring of per-step (reference, candidate) trace pairs."""

    def __init__(self, window: int = 4, spill_dir: str | None = None,
                 spill_keep: int = 8):
        self.window = max(1, int(window))
        self.spill_dir = spill_dir
        self.spill_keep = max(0, int(spill_keep))
        self._mem: OrderedDict[int, tuple[Trace, Trace]] = OrderedDict()
        self._spilled: OrderedDict[int, str] = OrderedDict()
        self._pinned: set[int] = set()
        self.spill_count = 0
        self.drop_count = 0

    # ---- introspection -----------------------------------------------------
    @property
    def in_memory(self) -> list[int]:
        return list(self._mem)

    @property
    def on_disk(self) -> list[int]:
        return list(self._spilled)

    @property
    def pinned(self) -> set[int]:
        return set(self._pinned)

    def __contains__(self, step: int) -> bool:
        return step in self._mem or step in self._spilled

    # ---- ring --------------------------------------------------------------
    def put(self, step: int, ref: Trace, cand: Trace) -> None:
        self._mem[step] = (ref, cand)
        self._evict()

    def pin(self, step: int) -> bool:
        """Mark a step as evidence (never dropped).  False if the step was
        already evicted without spill — nothing left to preserve."""
        if step not in self._mem and step not in self._spilled:
            return False
        self._pinned.add(step)
        return True

    def get(self, step: int) -> tuple[Trace, Trace]:
        if step in self._mem:
            return self._mem[step]
        if step in self._spilled:
            root = self._spilled[step]
            return (load_trace(os.path.join(root, "ref")),
                    load_trace(os.path.join(root, "cand")))
        raise KeyError(f"step {step} not retained (window={self.window}, "
                       f"spill={'on' if self.spill_dir else 'off'})")

    def _evict(self) -> None:
        if self.spill_dir is not None:
            # memory stays flat: everything past the window spills, pinned
            # included (the disk copy is the durable one)
            while len(self._mem) > self.window:
                step, (ref, cand) = self._mem.popitem(last=False)
                self._spill(step, ref, cand)
        else:
            # no spill backing: pinned evidence stays live and does not
            # count against the window; oldest unpinned steps drop
            unpinned = [s for s in self._mem if s not in self._pinned]
            while len(unpinned) > self.window:
                del self._mem[unpinned.pop(0)]
                self.drop_count += 1
        self._prune_disk()

    def _spill(self, step: int, ref: Trace, cand: Trace) -> None:
        root = os.path.join(self.spill_dir, f"step_{step:06d}")
        save_trace(os.path.join(root, "ref"), ref, step=step)
        save_trace(os.path.join(root, "cand"), cand, step=step)
        self._spilled[step] = root
        self.spill_count += 1

    def _prune_disk(self) -> None:
        if self.spill_dir is None:
            return
        unpinned = [s for s in self._spilled if s not in self._pinned]
        while len(unpinned) > self.spill_keep:
            s = unpinned.pop(0)
            shutil.rmtree(self._spilled.pop(s), ignore_errors=True)
