"""DeepSeek-V2 236B — MoE with Multi-head Latent Attention. [arXiv:2405.04434]

60L d_model=5120 128H (GQA kv=128) d_ff(expert)=1536 vocab=102400,
MoE 160 routed top-6 + 2 shared experts, MLA kv_lora_rank=512.
First layer uses a dense FFN (d_ff=12288) as in the release.
"""
from repro.configs.base import ArchConfig, MoEConfig, MLAConfig, register

CONFIG = register(ArchConfig(
    name="deepseek-v2-236b",
    arch_type="moe",
    n_layers=60,
    d_model=5120,
    n_heads=128,
    n_kv_heads=128,
    d_head=128,
    d_ff=1536,                       # expert hidden size
    vocab=102_400,
    attn="mla",
    mla=MLAConfig(kv_lora_rank=512, q_lora_rank=1536,
                  qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128),
    moe=MoEConfig(n_experts=160, top_k=6, n_shared=2,
                  d_ff_expert=1536, d_ff_dense=12288, n_dense_layers=1),
    param_dtype="bfloat16",
    source="arXiv:2405.04434",
))
