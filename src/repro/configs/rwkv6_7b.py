"""RWKV-6 "Finch" 7B — attention-free RNN w/ data-dependent decay. [arXiv:2404.05892]

32L d_model=4096 d_ff=14336 vocab=65536. Heads = d_model / 64.
"""
from repro.configs.base import ArchConfig, SSMConfig, register

CONFIG = register(ArchConfig(
    name="rwkv6-7b",
    arch_type="ssm",
    n_layers=32,
    d_model=4096,
    n_heads=64,            # rwkv6 heads: d_model / d_head(64)
    n_kv_heads=64,
    d_head=64,
    d_ff=14336,
    vocab=65536,
    attn="none",
    ssm=SSMConfig(kind="rwkv6", d_head=64, chunk=128, decay_lora=64, mix_lora=32),
    param_dtype="bfloat16",
    source="arXiv:2404.05892",
))
