"""TinyLlama 1.1B — llama2-arch small. [arXiv:2401.02385]

22L d_model=2048 32H (GQA kv=4) d_ff=5632 vocab=32000.
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="tinyllama-1.1b",
    arch_type="dense",
    n_layers=22,
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    d_ff=5632,
    vocab=32000,
    param_dtype="bfloat16",
    source="arXiv:2401.02385",
))
