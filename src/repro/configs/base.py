"""Architecture & input-shape configuration system.

Every assigned architecture is an ``ArchConfig`` registered under its public id
(``--arch <id>``).  ``ArchConfig.reduced()`` yields the CPU-smoke variant of the
same family (<=2 layers, d_model<=512, <=4 experts) used by the per-arch smoke
tests; the full configs are exercised only through the dry-run
(ShapeDtypeStruct, no allocation).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional

# ---------------------------------------------------------------------------
# Input shapes (assigned)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# Architecture config
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 0
    top_k: int = 0
    n_shared: int = 0          # shared (always-on) experts, deepseek-style
    d_ff_expert: int = 0       # per-expert hidden size
    d_ff_dense: int = 0        # dense FFN hidden for non-MoE layers (deepseek layer 0)
    n_dense_layers: int = 0    # leading layers that use a dense FFN instead of MoE
    router_aux_coef: float = 0.01
    capacity_factor: float = 2.0   # <= 0 means dropless (cap = n_tokens)


@dataclass(frozen=True)
class MLAConfig:
    kv_lora_rank: int = 512
    q_lora_rank: int = 0       # 0 => full-rank q projection
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class SSMConfig:
    kind: str = "mamba2"        # "mamba2" | "rwkv6"
    d_state: int = 64           # mamba2 SSM state size
    d_head: int = 64            # SSM head dim
    expand: int = 2             # d_inner = expand * d_model
    conv_kernel: int = 4
    chunk: int = 128            # chunked-scan block length
    # rwkv6
    decay_lora: int = 64        # rank of the data-dependent decay LoRA (Finch)
    mix_lora: int = 32          # rank of the data-dependent token-shift LoRA


@dataclass(frozen=True)
class HybridConfig:
    attn_every: int = 6         # apply the shared attention block every N ssm blocks
    shared_attn: bool = True    # single shared-parameter transformer block (zamba2)


@dataclass(frozen=True)
class ArchConfig:
    name: str
    arch_type: str              # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: int = 0             # default d_model // n_heads
    source: str = ""            # citation

    # attention flavour
    attn: str = "full"          # full | swa | mla | none
    window: int = 0             # sliding-window size when attn == "swa"
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    causal: bool = True         # False => encoder-only (hubert)

    tie_embeddings: bool = False

    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    hybrid: Optional[HybridConfig] = None

    # modality frontend stubs
    vision_dim: int = 0         # vlm: incoming patch-embedding feature dim
    n_image_tokens: int = 0     # vlm: patch tokens per sample (anyres tiles flattened)
    audio_dim: int = 0          # audio: incoming frame-feature dim

    # numerics / lowering
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    scan_layers: bool = True    # scan over stacked layers (big configs)
    remat: bool = True
    remat_policy: str = "full"  # "full" | "dots" (save matmul outputs:
                                # -24%% train FLOPs for +per-layer saves)

    def __post_init__(self):
        if self.d_head == 0:
            object.__setattr__(self, "d_head", self.d_model // self.n_heads)

    # ---- derived -----------------------------------------------------------
    @property
    def is_decoder(self) -> bool:
        return self.causal and self.arch_type != "audio"

    def supports_shape(self, shape: InputShape) -> tuple[bool, str]:
        """Whether (self, shape) is a live pair; returns (ok, reason-if-skip)."""
        if shape.kind == "decode" and not self.is_decoder:
            return False, "encoder-only architecture has no decode step"
        if shape.name == "long_500k":
            sub_quadratic = (
                self.arch_type in ("ssm", "hybrid")
                or self.attn == "swa"
            )
            if not sub_quadratic:
                return False, "pure full-attention arch; 512k decode needs sub-quadratic attention"
        return True, ""

    def reduced(self) -> "ArchConfig":
        """CPU smoke variant of the same family: 2 layers, d_model<=512, <=4 experts."""
        d_model = min(self.d_model, 256)
        n_heads = min(self.n_heads, 4)
        n_kv = max(1, min(self.n_kv_heads, n_heads))
        kw = dict(
            n_layers=2,
            d_model=d_model,
            n_heads=n_heads,
            n_kv_heads=n_kv,
            d_head=d_model // n_heads,
            d_ff=min(self.d_ff, 512),
            vocab=min(self.vocab, 512),
            window=min(self.window, 64) if self.window else 0,
            vision_dim=min(self.vision_dim, 64) if self.vision_dim else 0,
            n_image_tokens=min(self.n_image_tokens, 16) if self.n_image_tokens else 0,
            audio_dim=min(self.audio_dim, 64) if self.audio_dim else 0,
            scan_layers=False,
            remat=False,
            compute_dtype="float32",
            param_dtype="float32",
        )
        if self.moe is not None:
            kw["moe"] = MoEConfig(
                n_experts=min(self.moe.n_experts, 4),
                top_k=min(self.moe.top_k, 2),
                n_shared=min(self.moe.n_shared, 1),
                d_ff_expert=min(self.moe.d_ff_expert, 128),
                d_ff_dense=min(self.moe.d_ff_dense, 256) if self.moe.d_ff_dense else 0,
                n_dense_layers=min(self.moe.n_dense_layers, 1),
                capacity_factor=0.0,   # dropless: exact differential testing
            )
        if self.mla is not None:
            kw["mla"] = MLAConfig(
                kv_lora_rank=64, q_lora_rank=0, qk_nope_dim=32, qk_rope_dim=16,
                v_head_dim=32,
            )
        if self.ssm is not None:
            kw["ssm"] = dataclasses.replace(
                self.ssm, d_state=16, d_head=32, chunk=32, decay_lora=16, mix_lora=8)
        if self.hybrid is not None:
            kw["hybrid"] = dataclasses.replace(self.hybrid, attn_every=2)
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: dict[str, ArchConfig] = {}


def register(cfg: ArchConfig) -> ArchConfig:
    if cfg.name in _REGISTRY:
        raise ValueError(f"duplicate arch config {cfg.name!r}")
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ArchConfig:
    _ensure_loaded()
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}") from None


def list_configs() -> list[str]:
    _ensure_loaded()
    return sorted(_REGISTRY)


_LOADED = False


def _ensure_loaded():
    global _LOADED
    if _LOADED:
        return
    _LOADED = True
    # import every per-arch module so it registers itself
    from repro.configs import (  # noqa: F401
        deepseek_v2_236b, rwkv6_7b, codeqwen15_7b, zamba2_7b, qwen15_110b,
        mixtral_8x7b, qwen3_32b, llava_next_34b, tinyllama_11b, hubert_xlarge,
        gpt_paper,
    )
