"""LLaVA-NeXT 34B — VLM; anyres patch tiles + text. [hf:llava-hf/llava-v1.6-mistral-7b-hf]

Backbone: 60L d_model=7168 56H (GQA kv=8) d_ff=20480 vocab=64000.
Vision frontend (ViT + projector input) is a STUB per assignment: input_specs
provides precomputed patch embeddings (vision_dim=1024) which the trained
projector maps into d_model and interleaves ahead of the text tokens.
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="llava-next-34b",
    arch_type="vlm",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=20480,
    vocab=64000,
    vision_dim=1024,
    n_image_tokens=2880,      # anyres: 5 tiles x 576 patches
    param_dtype="bfloat16",
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf",
))
