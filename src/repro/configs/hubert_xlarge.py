"""HuBERT X-Large — encoder-only audio transformer. [arXiv:2106.07447]

48L d_model=1280 16H (kv=16) d_ff=5120 vocab=504 (k-means codebook targets).
Conv/mel feature-extractor frontend is a STUB per assignment: input_specs
provides precomputed frame features (audio_dim=512); the model projects them
to d_model and runs the bidirectional encoder with a masked-prediction head.
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="hubert-xlarge",
    arch_type="audio",
    n_layers=48,
    d_model=1280,
    n_heads=16,
    n_kv_heads=16,
    d_ff=5120,
    vocab=504,
    causal=False,
    audio_dim=512,
    param_dtype="bfloat16",
    source="arXiv:2106.07447",
))
