"""GPT — the paper's own evaluation model family (Megatron GPT).

TTrace's figures use GPT with up to 128 layers; this config is the paper-
faithful subject model for the threshold-curve and bug-table reproductions.
Depth/width are overridable by the benchmarks (see benchmarks/threshold_curves).
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="gpt-paper",
    arch_type="dense",
    n_layers=12,
    d_model=512,
    n_heads=8,
    n_kv_heads=8,
    d_ff=2048,
    vocab=50304,
    tie_embeddings=True,
    scan_layers=False,
    remat=False,
    source="TTrace paper §6 (Megatron GPT)",
))
