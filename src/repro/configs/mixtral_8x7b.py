"""Mixtral 8x7B — MoE 8 experts top-2, sliding-window attention. [arXiv:2401.04088]

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000, window=4096.
"""
from repro.configs.base import ArchConfig, MoEConfig, register

CONFIG = register(ArchConfig(
    name="mixtral-8x7b",
    arch_type="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=32000,
    attn="swa",
    window=4096,
    moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=14336),
    param_dtype="bfloat16",
    source="arXiv:2401.04088",
))
