"""Zamba2-7B — Mamba2 backbone + shared attention blocks. [arXiv:2411.15242]

81L d_model=3584 32H (GQA kv=32) d_ff=14336 vocab=32000 ssm_state=64.
A single shared-parameter transformer block is applied every 6 mamba blocks.
"""
from repro.configs.base import ArchConfig, SSMConfig, HybridConfig, register

CONFIG = register(ArchConfig(
    name="zamba2-7b",
    arch_type="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    d_ff=14336,
    vocab=32000,
    ssm=SSMConfig(kind="mamba2", d_state=64, d_head=64, expand=2, chunk=128),
    hybrid=HybridConfig(attn_every=6, shared_attn=True),
    param_dtype="bfloat16",
    source="arXiv:2411.15242",
))
