"""ZeRO-1 distributed optimizer (sharded fp32 masters) + its silent bugs.

Adam is elementwise, so partitioning the master/m/v state across DP ranks and
all-gathering updated params is mathematically identical to the full update —
which is exactly why its bugs are *silent*.  We model the partitioning
explicitly on the flattened parameter and inject:

* ``zero_skipped_update`` (paper bug 9): the all-gather after the step
  returns the PRE-update values for the last rank's partition — those
  elements simply never train.
* ``zero_untied_embedding`` (paper bug 5): with tied embeddings, the
  embedding and LM-head references are owned by different ZeRO partitions;
  the tied gradient contribution of the LM-head side is lost for the
  embedding's owner.  Emulated by halving the embedding's applied gradient —
  the same "tied weights silently drift from the reference" signature.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.optim.adamw import AdamW


def zero1_update(opt: AdamW, params, grads, state, dp: int,
                 bugs=frozenset()):
    """Semantics-equivalent ZeRO-1 step (bugs aside)."""
    if "zero_untied_embedding" in bugs:
        def fix(path, g):
            name = ".".join(str(getattr(k, "key", k)) for k in path)
            return g * 0.5 if "word_embeddings" in name else g
        grads = jax.tree_util.tree_map_with_path(fix, grads)

    new_params, new_state, info = opt.update(params, grads, state)

    if "zero_skipped_update" in bugs:
        def stale(newp, oldp):
            n = newp.size
            cut = (n // dp) * (dp - 1)
            if newp.ndim == 0:
                # cut = 0 for a single element: the whole leaf is in the
                # last (stale) partition, matching the flat-concat semantics
                return oldp.astype(newp.dtype)
            # elementwise flat-index mask instead of reshape+concat: global
            # reshapes of sharded leaves miscompile under GSPMD (jax 0.4.x),
            # and the supervisor runs this update inside a jitted step over
            # mesh-sharded params; iota arithmetic is sharding-safe
            strides = np.cumprod((newp.shape[1:] + (1,))[::-1])[::-1]
            flat_idx = sum(
                jax.lax.broadcasted_iota(jnp.int32, newp.shape, d) * int(s)
                for d, s in enumerate(strides))
            return jnp.where(flat_idx < cut, newp,
                             oldp.astype(newp.dtype))
        new_params = jax.tree.map(stale, new_params, params)
        # masters stay consistent with the (buggy) gathered params
        new_state = dict(new_state)
        new_state["master"] = jax.tree.map(
            lambda p: p.astype(jnp.float32), new_params)
    return new_params, new_state, info
