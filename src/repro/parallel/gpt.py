"""Distributed GPT/Llama/MoE candidate model (manual collectives).

Mirrors the reference ``repro.models.model.Model`` tap-for-tap: the same
canonical module names, the same block structure — but built from the
manual-parallel layers so TP/SP/CP/EP silent bugs have somewhere to live.
Runs inside a shard_map body on a ("dp","cp","tp") mesh.

Supports the paper's evaluation families: dense GPT/Llama blocks and MoE
blocks (top-k router + expert parallelism over the tp axis).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core.tap import ensure_ctx
from repro.models.layers import rmsnorm
from repro.models.moe import router_topk
from repro.parallel.layers import (
    AX_CP, AX_DP, AX_TP, axis_index, axis_size, g_copy, g_reduce,
    g_reduce_over, local_positions, sp_gather, tp_gqa_attention,
    tp_swiglu_mlp, vocab_parallel_ce, vocab_parallel_embedding,
)
from repro.models.moe import load_balance_loss


# ---------------------------------------------------------------------------
# Expert-parallel MoE (experts sharded over the tp axis)
# ---------------------------------------------------------------------------

def tp_moe(p_local, cfg: ArchConfig, x, sp: bool, bugs=frozenset(),
           ctx=None):
    """Router replicated; experts sharded over tp.  Each rank routes ALL
    (local-sequence) tokens, processes the ones assigned to its local
    experts, and the outputs are summed over tp.

    ``moe_router_not_synced`` (paper bug 6): the router weights differ per
    rank (missed broadcast at init) so ranks disagree about routing."""
    ctx = ensure_ctx(ctx)
    x = ctx.tap("input", x)
    if sp:
        x = sp_gather(x)
    elif axis_size(AX_TP) > 1:
        x = g_copy(x)
    m = cfg.moe
    tp = axis_size(AX_TP)
    El = m.n_experts // tp
    B, S, d = x.shape
    T = B * S
    xt = x.reshape(T, d)

    router = p_local["router"]
    if "moe_router_not_synced" in bugs:
        # per-rank drift: the weights each rank *thinks* are synced
        r = axis_index(AX_TP).astype(jnp.float32)
        router = router * (1.0 + 0.05 * r)
    logits = xt.astype(jnp.float32) @ router
    logits = ctx.tap("router_logits",
                     logits.reshape(B, S, -1)).reshape(T, -1)
    top_p, top_e = router_topk(logits, m.top_k)

    from repro.models.moe import expert_capacity
    cap = expert_capacity(T, m)
    k = m.top_k
    flat_e = top_e.reshape(T * k)
    flat_w = top_p.reshape(T * k)
    flat_tok = jnp.repeat(jnp.arange(T), k)
    order = jnp.argsort(flat_e, stable=True)
    se, sw, stok = flat_e[order], flat_w[order], flat_tok[order]
    start = jnp.searchsorted(se, jnp.arange(m.n_experts), side="left")
    pos = jnp.arange(T * k) - start[se]
    e0 = axis_index(AX_TP) * El
    local = (se >= e0) & (se < e0 + El) & (pos < cap)
    le = jnp.where(local, se - e0, 0)
    lp = jnp.where(local, pos, 0)

    buf = jnp.zeros((El, cap, d), x.dtype)
    buf = buf.at[le, lp].add(jnp.where(local[:, None], xt[stok], 0.0
                                       ).astype(x.dtype))
    e = p_local["experts"]
    h = (jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, e["gate"].astype(x.dtype)))
         * jnp.einsum("ecd,edf->ecf", buf, e["up"].astype(x.dtype)))
    out_buf = jnp.einsum("ecf,efd->ecd", h, e["down"].astype(x.dtype))
    gathered = out_buf[le, lp]
    contrib = jnp.where(local[:, None],
                        gathered.astype(jnp.float32) * sw[:, None], 0.0)
    yt = jnp.zeros((T, d), jnp.float32).at[stok].add(contrib)
    y = yt.reshape(B, S, d).astype(x.dtype)           # local-expert partials
    if sp:
        y = jax.lax.psum_scatter(y, AX_TP, scatter_dimension=1, tiled=True)
    else:
        y = g_reduce(y)                               # combine expert shards
    y = ctx.tap("output", y)
    # Load-balance statistics.  Divided by tp so that, like the dispatch
    # path, each rank holds a PARTIAL contribution: the caller reduces over
    # (dp, cp, tp) with a conjugate psum, which makes both the router-grad
    # all-reduce and the router_logits probe-gradient psum exact.
    probs = jax.nn.softmax(logits, axis=-1)
    count = jnp.zeros((m.n_experts,), jnp.float32).at[
        top_e.reshape(-1)].add(1.0)
    stats = {"probs_sum": probs.sum(0) / tp, "count": count / tp,
             "n_tokens": jnp.float32(T) / tp}
    return y, stats


# ---------------------------------------------------------------------------
# Full model body
# ---------------------------------------------------------------------------

def parallel_block(p, cfg: ArchConfig, x, q_pos, li: int, sp: bool,
                   moe: bool, bugs, ctx):
    ctx = ensure_ctx(ctx)
    with ctx.scope(f"layers.{li}"):
        h = rmsnorm(p["input_norm"], x)
        with ctx.scope("self_attention"):
            a = tp_gqa_attention(p["self_attention"], cfg, h, q_pos, sp,
                                 bugs=bugs, ctx=ctx)
        x = x + a
        h = rmsnorm(p["post_attn_norm"], x)
        stats = None
        with ctx.scope("mlp"):
            if moe:
                mo, stats = tp_moe(p["mlp"], cfg, h, sp, bugs=bugs, ctx=ctx)
            else:
                mo = tp_swiglu_mlp(p["mlp"], h, sp, bugs=bugs, ctx=ctx)
        x = x + mo
    return x, stats


def parallel_gpt_loss(params, batch, cfg: ArchConfig, sp: bool,
                      bugs=frozenset(), ctx=None):
    """Returns (grad_loss, report_loss): ``grad_loss`` follows the explicit
    dp/cp gradient-averaging convention (aux pre-multiplied by dp*cp);
    ``report_loss`` is this rank's true local loss (ce_mean + aux).
    Runs inside shard_map; ``batch`` tokens/labels are (B_local, S_local)
    zigzag-layout shards."""
    ctx = ensure_ctx(ctx)
    tokens, labels = batch["tokens"], batch["labels"]
    cp = axis_size(AX_CP)
    S_local = tokens.shape[1]
    S_global = S_local * cp
    q_pos = local_positions(S_global, cp)

    with ctx.scope("embedding"):
        h = vocab_parallel_embedding(
            params["embedding"]["word_embeddings"], tokens, cfg.vocab,
            bugs=bugs, reduce="scatter" if sp else "psum")
        h = h.astype(jnp.dtype(cfg.compute_dtype))
        h = ctx.tap("output", h)

    moe = cfg.moe is not None
    all_stats = []
    for li, p in enumerate(params["layers"]):
        h, stats = parallel_block(p, cfg, h, q_pos, li, sp, moe, bugs, ctx)
        if stats is not None:
            all_stats.append(stats)

    h = rmsnorm(params["final_norm"], h)
    h = ctx.tap("final_norm_out", h)
    if sp:
        h = sp_gather(h)
    elif axis_size(AX_TP) > 1:
        h = g_copy(h)
    e = (params["embedding"]["word_embeddings"] if cfg.tie_embeddings
         else params["lm_head"])
    logits_local = h @ e.T.astype(h.dtype)            # (B, S_loc, V/tp)
    nll = vocab_parallel_ce(logits_local, labels, cfg.vocab)
    ce = jnp.mean(nll)

    # router load-balance aux loss from GLOBAL statistics: stats are summed
    # across dp/cp with a conjugate reduce so each rank's backward receives
    # its own piece of the global gradient.  The (dp*cp) factor compensates
    # the caller's explicit psum/(dp*cp) gradient averaging.
    if all_stats:
        axes = tuple(a for a in ("dp", "cp", "tp") if axis_size(a) > 1)
        dpcp = axis_size(AX_DP) * axis_size(AX_CP)
        aux = jnp.zeros((), jnp.float32)
        m = cfg.moe
        for st in all_stats:
            ps = g_reduce_over(st["probs_sum"], axes)
            cn = g_reduce_over(st["count"], axes)
            n_g = g_reduce_over(st["n_tokens"], axes)
            aux += load_balance_loss(ps / n_g, cn / (n_g * m.top_k),
                                     m.n_experts) * m.router_aux_coef
        return ce + aux * dpcp, ce + aux
    return ce, ce
