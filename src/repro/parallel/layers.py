"""Manual tensor/sequence/context-parallel layers (shard_map bodies).

These are the Megatron-style hand-written distributed layers — explicit
``psum`` / ``all_gather`` / ``psum_scatter`` / ``ppermute`` collectives on a
("dp", "cp", "tp") mesh — i.e. the *candidate* side of TTrace's differential
test.  Every function takes ``bugs`` (frozenset of ids from
repro.bugs.registry) and injects the corresponding silent bug when asked:
this file is where Table 1's bug taxonomy lives.

All functions run INSIDE a shard_map body; "local" means per-device shard.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.tap import ensure_ctx
from repro.models.attention import NEG_INF, attention_ref
from repro.models.layers import apply_rope, rmsnorm

AX_DP, AX_CP, AX_TP = "dp", "cp", "tp"


def axis_size(name):
    # jax.lax.axis_size only exists on newer jax; psum of the python scalar
    # 1 is the version-stable spelling — it folds to the static axis size
    # without tracing
    try:
        return jax.lax.psum(1, name)
    except NameError:
        return 1


def axis_index(name):
    return jax.lax.axis_index(name)


# ---------------------------------------------------------------------------
# Megatron's conjugate communication operators (f / g).
#
# Under shard_map with unchecked replication, a bare ``psum`` does not know
# whether its cotangent is replicated, so AD through it double-counts.  The
# classic fix — exactly what Megatron's ``copy_to_tensor_model_parallel_region``
# and ``reduce_from_tensor_model_parallel_region`` do — is a conjugate pair:
#   g_copy:   identity forward, psum backward   (enter column-parallel compute)
#   g_reduce: psum forward, identity backward   (leave row-parallel compute)
# ---------------------------------------------------------------------------

@jax.custom_vjp
def g_copy(x):
    return x


def _g_copy_fwd(x):
    return x, None


def _g_copy_bwd(_, g):
    return (jax.lax.psum(g, AX_TP),)


g_copy.defvjp(_g_copy_fwd, _g_copy_bwd)


@jax.custom_vjp
def g_reduce(x):
    return jax.lax.psum(x, AX_TP)


def _g_reduce_fwd(x):
    return jax.lax.psum(x, AX_TP), None


def _g_reduce_bwd(_, g):
    return (g,)


g_reduce.defvjp(_g_reduce_fwd, _g_reduce_bwd)


def g_reduce_over(x, axes):
    """psum-forward / identity-backward over arbitrary axes (the conjugate
    reduce for cross-rank statistics like the MoE load-balance stats)."""
    if not axes:
        return x

    @jax.custom_vjp
    def f(x):
        return jax.lax.psum(x, axes)

    def fwd(x):
        return jax.lax.psum(x, axes), None

    def bwd(_, g):
        return (g,)

    f.defvjp(fwd, bwd)
    return f(x)


# ---------------------------------------------------------------------------
# Zigzag context-parallel layout helpers (paper Fig 6: striped attention)
# ---------------------------------------------------------------------------

def zigzag_order(cp: int) -> list[int]:
    """Chunk order such that contiguous rank splits give zigzag stripes:
    rank r owns chunks (r, 2cp-1-r)."""
    out = []
    for r in range(cp):
        out += [r, 2 * cp - 1 - r]
    return out


def permute_to_zigzag(x, cp: int, dim: int):
    if cp == 1:
        return x
    order = zigzag_order(cp)
    chunks = jnp.split(x, 2 * cp, axis=dim)
    return jnp.concatenate([chunks[c] for c in order], axis=dim)


def permute_from_zigzag(x, cp: int, dim: int):
    if cp == 1:
        return x
    order = zigzag_order(cp)
    inv = [order.index(i) for i in range(2 * cp)]
    chunks = jnp.split(x, 2 * cp, axis=dim)
    return jnp.concatenate([chunks[c] for c in inv], axis=dim)


def local_positions(seq_global: int, cp: int):
    """Absolute token positions of this rank's zigzag stripes (traced)."""
    if cp == 1:
        return jnp.arange(seq_global)
    r = axis_index(AX_CP)
    chunk = seq_global // (2 * cp)
    a = r * chunk + jnp.arange(chunk)
    b = (2 * cp - 1 - r) * chunk + jnp.arange(chunk)
    return jnp.concatenate([a, b])


# ---------------------------------------------------------------------------
# Vocab-parallel embedding (bug 1 lives here)
# ---------------------------------------------------------------------------

def vocab_parallel_embedding(w_local, tokens, vocab: int, bugs=frozenset(),
                             reduce: str = "psum"):
    """w_local: (V/tp, d) — this rank's vocab rows.  Wrong ownership mask
    (``tp_wrong_embedding_mask``) lets boundary tokens be embedded by two
    ranks and double-counted by the all-reduce — paper bug 1.

    ``reduce``: "psum" (full output) or "scatter" (sequence-parallel:
    reduce-scatter along seq, output (B, S/tp, d))."""
    tp = axis_size(AX_TP)
    per = vocab // tp
    start = axis_index(AX_TP) * per
    if "tp_wrong_embedding_mask" in bugs:
        # wrong upper bound: this rank also claims the next rank's lower
        # half; those tokens hit the clipped last row AND get double-counted
        # by the all-reduce (paper bug 1: wrong forward + gradients)
        own = (tokens >= start) & (tokens < start + per + per // 2)
    else:
        own = (tokens >= start) & (tokens < start + per)
    local_idx = jnp.clip(tokens - start, 0, per - 1)
    emb = w_local[local_idx]
    emb = jnp.where(own[..., None], emb, 0.0)
    if reduce == "scatter":
        return jax.lax.psum_scatter(emb, AX_TP, scatter_dimension=1,
                                    tiled=True)
    return g_reduce(emb)


# ---------------------------------------------------------------------------
# Column / row parallel linears
# ---------------------------------------------------------------------------

def column_linear(p_local, x):
    """weights sharded on the OUTPUT dim; no forward comm."""
    y = x @ p_local["w"].astype(x.dtype)
    if "b" in p_local:
        y = y + p_local["b"].astype(x.dtype)
    return y


def one_rank(x, axis):
    """Model a missing/wrong collective silently: in the real framework every
    rank keeps its own (conflicting) partial value — the paper's "conflicting
    tensor".  Our single-trace runner takes rank 0's partial so the result is
    one consistent, silently-wrong value."""
    return jax.lax.all_gather(x, axis, axis=0)[0]


def row_linear(p_local, x_local, bugs=frozenset(), reduce_out=True,
               bug_axis_id="tp_wrong_allreduce_axis",
               bug_missing_id="tp_missing_row_psum"):
    """weights sharded on the INPUT dim; output needs a psum over tp.

    Bugs: wrong all-reduce group (psum over dp — paper bug 7 analogue) or a
    missing all-reduce (partial sums downstream — paper bugs 6/11 class)."""
    y = x_local @ p_local["w"].astype(x_local.dtype)
    if reduce_out:
        if bug_missing_id in bugs:
            y = one_rank(y, AX_TP)                # M-CM: forgot the psum
        elif bug_axis_id in bugs:
            y = jax.lax.psum(y, AX_DP)            # W-CM: wrong group
            y = one_rank(y, AX_TP)
        else:
            y = g_reduce(y)
    if "b" in p_local:
        y = y + p_local["b"].astype(y.dtype)
    return y


# ---------------------------------------------------------------------------
# Sequence parallelism (gather/scatter along seq over the tp axis)
# ---------------------------------------------------------------------------

def sp_gather(x, dim=1):
    return jax.lax.all_gather(x, AX_TP, axis=dim, tiled=True)


def sp_scatter(x, dim=1):
    return jax.lax.psum_scatter(x, AX_TP, scatter_dimension=dim, tiled=True)


# ---------------------------------------------------------------------------
# Context-parallel attention (zigzag stripes; KV all-gather)
# ---------------------------------------------------------------------------

def _cp_attention_math(q, k, v, q_pos, k_pos):
    B, Q, H, D = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    qg = q.reshape(B, Q, Hkv, G, D)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) / jnp.sqrt(D).astype(jnp.float32)
    mask = k_pos[None, :] <= q_pos[:, None]
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return o.reshape(B, Q, H, D).astype(q.dtype)


def cp_attention(q, k, v, q_pos, bugs=frozenset()):
    """q,k,v local zigzag stripes (B, S/cp, H_local, D); gathers K/V over cp.

    ``cp_wrong_attention_grad`` (paper bug 13): forward is correct but the
    backward uses the FIRST stripe's positions for both stripes, dropping the
    second stripe's causal-mask correction."""
    cp = axis_size(AX_CP)
    if cp == 1:
        return _cp_attention_math(q, k, v, q_pos, q_pos)
    kg = jax.lax.all_gather(k, AX_CP, axis=1, tiled=True)
    vg = jax.lax.all_gather(v, AX_CP, axis=1, tiled=True)
    k_pos = jax.lax.all_gather(q_pos, AX_CP, axis=0, tiled=True)

    if "cp_wrong_attention_grad" not in bugs:
        return _cp_attention_math(q, kg, vg, q_pos, k_pos)

    half = q_pos.shape[0] // 2
    bad_q_pos = jnp.concatenate([q_pos[:half], q_pos[:half]])

    @jax.custom_vjp
    def buggy(q, kg, vg):
        return _cp_attention_math(q, kg, vg, q_pos, k_pos)

    def fwd(q, kg, vg):
        return buggy(q, kg, vg), (q, kg, vg)

    def bwd(res, g):
        q, kg, vg = res
        _, vjp = jax.vjp(
            lambda a, b, c: _cp_attention_math(a, b, c, bad_q_pos, k_pos),
            q, kg, vg)
        return vjp(g)

    buggy.defvjp(fwd, bwd)
    return buggy(q, kg, vg)


# ---------------------------------------------------------------------------
# TP attention block (heads sharded over tp)
# ---------------------------------------------------------------------------

def tp_gqa_attention(p_local, cfg, x, q_pos, sp: bool, bugs=frozenset(),
                     ctx=None):
    """x: (B, S_local, d_model) — seq local under SP/CP, else full.
    Head-parallel attention with fused column-parallel linear_qkv and
    row-parallel linear_proj."""
    ctx = ensure_ctx(ctx)
    x = ctx.tap("input", x)
    tp = axis_size(AX_TP)
    H, Hkv, D = cfg.n_heads // tp, cfg.n_kv_heads // tp, cfg.d_head
    if sp:
        x = sp_gather(x)          # attention region runs on the full sequence
    elif tp > 1:
        x = g_copy(x)             # enter column-parallel compute
    B, S, _ = x.shape
    qkv = column_linear(p_local["linear_qkv"], x)
    q, k, v = jnp.split(qkv, [H * D, (H + Hkv) * D], axis=-1)
    q = q.reshape(B, S, H, D)
    k = k.reshape(B, S, Hkv, D)
    v = v.reshape(B, S, Hkv, D)
    if cfg.qk_norm:
        q = rmsnorm(p_local["q_norm"], q)
        k = rmsnorm(p_local["k_norm"], k)
    pos_b = jnp.broadcast_to(q_pos, (B,) + q_pos.shape)
    q = apply_rope(q, pos_b, cfg.rope_theta)
    k = apply_rope(k, pos_b, cfg.rope_theta)
    o = cp_attention(q, k, v, q_pos, bugs=bugs)
    o = o.reshape(B, S, H * D)
    o = ctx.tap("core_attn_out", o)
    pp = p_local["linear_proj"]
    if sp:
        yl = _matmul(o, pp["w"], stale_wgrad="sp_stale_wgrad" in bugs)
        y = jax.lax.psum_scatter(yl, AX_TP, scatter_dimension=1, tiled=True)
        if "b" in pp:
            y = y + pp["b"].astype(y.dtype)
    else:
        y = row_linear(pp, o, bugs=bugs,
                       bug_missing_id="attn_missing_row_psum")
    return ctx.tap("output", y)


def _matmul(o, w, stale_wgrad=False):
    """o @ w; with ``stale_wgrad`` (paper bug 11 — wrong gradients with
    comm/compute overlap) the forward and dgrad are correct but dW is
    computed from a half-zeroed activation, as if the overlapped backward
    all-gather returned a stale buffer."""
    if not stale_wgrad:
        return o @ w.astype(o.dtype)

    @jax.custom_vjp
    def f(o, w):
        return o @ w.astype(o.dtype)

    def fwd(o, w):
        return f(o, w), (o, w)

    def bwd(res, g):
        o, w = res
        do = g @ w.astype(g.dtype).T
        S = o.shape[1]
        o_stale = jnp.concatenate(
            [o[:, :S // 2], jnp.zeros_like(o[:, S // 2:])], axis=1)
        dw = jnp.einsum("bsi,bso->io", o_stale.astype(jnp.float32),
                        g.astype(jnp.float32)).astype(w.dtype)
        return do, dw
    f.defvjp(fwd, bwd)
    return f(o, w)


# ---------------------------------------------------------------------------
# TP MLP (column gate/up, row down)
# ---------------------------------------------------------------------------

def tp_swiglu_mlp(p_local, x, sp: bool, bugs=frozenset(), ctx=None):
    ctx = ensure_ctx(ctx)
    x = ctx.tap("input", x)
    if sp:
        x = sp_gather(x)
    elif axis_size(AX_TP) > 1:
        x = g_copy(x)
    h = (jax.nn.silu(column_linear(p_local["gate"], x))
         * column_linear(p_local["up"], x))
    y = _maybe_stale_recompute(h, bugs)
    if sp:
        yl = y @ p_local["down"]["w"].astype(y.dtype)
        out = jax.lax.psum_scatter(yl, AX_TP, scatter_dimension=1, tiled=True)
    else:
        out = row_linear(p_local["down"], y, bugs=bugs,
                         bug_axis_id="mlp_wrong_allreduce_axis")
    return ctx.tap("output", out)


def _maybe_stale_recompute(h, bugs):
    """``ar_stale_recompute`` (paper bug 2): activation recomputation uses an
    outdated input — forward is right, the backward sees a token-shifted h."""
    if "ar_stale_recompute" not in bugs:
        return h

    @jax.custom_vjp
    def f(h):
        return h

    def fwd(h):
        return h, (h,)

    def bwd(res, g):
        (h,) = res
        return (jnp.roll(g, 1, axis=1),)   # grad routed to shifted positions
    f.defvjp(fwd, bwd)
    return f(h)


# ---------------------------------------------------------------------------
# Vocab-parallel cross entropy
# ---------------------------------------------------------------------------

def vocab_parallel_ce(logits_local, labels, vocab: int):
    """logits_local: (B, S_local, V/tp).  Max/sumexp/gold psum'ed over tp.
    Returns per-token nll (B, S_local)."""
    tp = axis_size(AX_TP)
    per = vocab // tp
    start = axis_index(AX_TP) * per
    lf = logits_local.astype(jnp.float32)
    # max is a constant shift for stability — detach it (pmax has no AD rule;
    # the gradient is exact anyway since the shift cancels in lse - gold)
    m = jax.lax.pmax(jax.lax.stop_gradient(jnp.max(lf, axis=-1)), AX_TP)
    se = g_reduce(jnp.sum(jnp.exp(lf - m[..., None]), axis=-1))
    lse = jnp.log(se) + m
    own = (labels >= start) & (labels < start + per)
    lidx = jnp.clip(labels - start, 0, per - 1)
    gold_local = jnp.take_along_axis(lf, lidx[..., None], axis=-1)[..., 0]
    gold = g_reduce(jnp.where(own, gold_local, 0.0))
    return lse - gold
