"""Real multi-device 1F1B pipeline parallelism with per-rank trace merging.

Unlike ``parallel.pp`` — a single-controller *staged* candidate that bakes
the canonical stage-local -> global renaming into one jitted loss — this
engine runs the pipeline the way a PP framework does (paper §5, Fig 5):

* the model is partitioned onto **per-stage single-device submeshes** built
  from the process's (forced-host) device list: stage ``s`` holds only its
  own layer slice, plus the embedding on stage 0 and the final norm /
  LM head on the last stage.  Tied embeddings are replicated on both ends
  and their gradients explicitly reduced across the two stages
  (Megatron-style tied-embedding all-reduce);
* execution follows the **1F1B microbatch schedule** (``stage_op_stream``
  per stage: warmup forwards, steady one-forward-one-backward, cooldown
  backwards) under **dependency-driven per-stage dispatch**: each stage's
  jitted op launches the moment its cross-stage input's device future
  exists — no host clock-tick linearization — with stage-boundary
  transfers issued at PRODUCE time through the ``BoundaryTransport`` seam
  (the one class a real-interconnect collective-permute implementation
  replaces) and a bounded per-stage activation stash (the 1F1B memory
  property: stage ``s`` stashes at most ``pp - s`` inputs);
* each (stage, microbatch) op emits a rank-LOCAL trace — stage-local layer
  names, microbatch-sized leaves — merged into the reference-shaped trace
  by the build-once ``core.merger.MergePlan`` (one jitted pack per stage:
  microbatch-axis concat + fused grad accumulation; names canonicalized
  via the same ``stage_layer_table`` the staged candidate uses) BEFORE any
  checking, numerically identical to ``merge_microbatch_traces``;
* the plan's packed per-stage gradients double as the source of the
  reference-named global tree for the (once-jitted) optimizer step.

Backward ops recompute their stage's forward from the stashed boundary
input inside ``jax.vjp`` (stage-granular activation checkpointing) — which
is exactly the surface the two schedule-layer bugs corrupt:

* ``pp_microbatch_order`` — the backward recompute reads the NEXT
  microbatch's stashed input, so gradients are accumulated against the
  wrong microbatch's activations.  Forward — and therefore the loss curve —
  is byte-identical to the correct schedule;
* ``pp_stale_boundary`` — stage ``i+1`` consumes the previous microbatch's
  boundary activation (a stale recv buffer).  Microbatch 0 is correct and
  every consumed tensor is a real activation, so the loss stays plausible.

Every per-stage forward/backward is jitted exactly once at engine build
(rewrites ride along as a dict *argument*, so localization-mode calls reuse
the same compiled steps per rewrite-name signature) — the supervisor's
``CandidateStep`` once-compiled contract.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.collector import (Trace, _make_probes, flatten_named,
                                  unflatten_named)
from repro.core.merger import MergePlan, canonical_stage_name
from repro.core.tap import TraceContext
from repro.models.model import block_apply
from repro.parallel.pp import stage_division, stage_layer_table


# ---------------------------------------------------------------------------
# Schedule (pure — property-tested in tests/test_pp1f1b.py)
# ---------------------------------------------------------------------------

def stage_tables(n_layers: int, pp_size: int,
                 bugs=frozenset()) -> list[list[tuple[int, int]]]:
    """Per-stage ``[(executed_layer, canonical_index), ...]`` — the flat
    ``stage_layer_table`` grouped by owning stage, i.e. the renaming each
    RANK would apply to its local trace (paper Fig 5)."""
    stages = stage_division(n_layers, pp_size, bugs)
    flat = stage_layer_table(n_layers, pp_size, bugs)
    out, i = [], 0
    for start, end in stages:
        out.append(flat[i:i + (end - start)])
        i += end - start
    return out


def stage_op_stream(pp_size: int, stage: int,
                    n_microbatches: int) -> list[tuple[str, int, int]]:
    """Canonical per-stage 1F1B op stream ``[("F"|"B", stage, mb), ...]``:
    ``min(M, pp - 1 - stage)`` warmup forwards, then one-forward-one-backward
    pairs, then cooldown backwards (Megatron's non-interleaved schedule)."""
    M = n_microbatches
    warm = min(M, pp_size - 1 - stage)
    ops = [("F", stage, m) for m in range(warm)]
    for i in range(M - warm):
        ops.append(("F", stage, warm + i))
        ops.append(("B", stage, i))
    ops += [("B", stage, m) for m in range(M - warm, M)]
    return ops


def walk_1f1b(streams, visit, max_per_visit: int | None = None) -> None:
    """Dependency-driven walk of per-stage 1F1B op streams: ``visit(d, s,
    m)`` fires as soon as the op's cross-stage dependency is met (forward
    (s, m) needs forward (s-1, m); backward (s, m) needs backward
    (s+1, m)), per-stage order fixed by the streams.  This is THE driver —
    the engine dispatches through it greedily (each stage runs as far
    ahead as its data allows) and ``schedule_1f1b`` replays it with
    ``max_per_visit=1`` (the clock-tick linearization), so the two can
    never drift."""
    S = len(streams)
    ptr = [0] * S
    done_f: set = set()
    done_b: set = set()
    remaining = sum(len(st) for st in streams)
    while remaining:
        progressed = False
        for s in range(S):
            taken = 0
            while ptr[s] < len(streams[s]) and (max_per_visit is None
                                                or taken < max_per_visit):
                d, _, m = streams[s][ptr[s]]
                ready = (d == "F" and (s == 0 or (s - 1, m) in done_f)) or \
                        (d == "B" and (s == S - 1 or (s + 1, m) in done_b))
                if not ready:
                    break
                visit(d, s, m)
                (done_f if d == "F" else done_b).add((s, m))
                ptr[s] += 1
                taken += 1
                remaining -= 1
                progressed = True
        if not progressed:       # impossible for a well-formed 1F1B stream
            raise RuntimeError("1F1B schedule deadlocked")


def schedule_1f1b(pp_size: int,
                  n_microbatches: int) -> list[tuple[str, int, int]]:
    """Global execution order: the clock-tick linearization of
    ``walk_1f1b`` (each stage advances at most one op per tick) — the host
    serialization of what per-rank processes execute concurrently."""
    streams = [stage_op_stream(pp_size, s, n_microbatches)
               for s in range(pp_size)]
    order: list[tuple[str, int, int]] = []
    walk_1f1b(streams, lambda d, s, m: order.append((d, s, m)),
              max_per_visit=1)
    return order


# ---------------------------------------------------------------------------
# Stage-boundary transport (the one-module seam for real interconnects)
# ---------------------------------------------------------------------------

class BoundaryTransport:
    """Stage-boundary activation/gradient communication for one iteration.

    The seam the engine sends/receives through — and the ONE module a real
    interconnect implementation (ICI collective-permute on a ``(pp,)`` mesh)
    would replace.  This host-device implementation issues the transfer at
    **send time** (``jax.device_put`` is async), so the copy to stage ``i+1``
    overlaps stage ``i``'s remaining compute instead of being issued only
    when the consumer is about to run.

    Buffers model per-link recv slots: ``recv`` does not consume (a stale
    consumer may re-read an old slot — the ``pp_stale_boundary`` surface);
    ``evict`` frees a slot once the schedule proves it dead, bounding live
    boundary buffers at two per stage pair.

    ``deadline_s`` (optional) bounds each recv: the consumer polls the
    transfer future and a producer that died or hung turns into a
    ``repro.supervise.watchdog.BoundaryTimeout`` — a loud, localized
    failure naming the stage link — instead of an infinite stall inside
    the schedule.  ``None`` (default) keeps the native blocking behavior.
    """

    def __init__(self, places, deadline_s=None):
        self.places = places
        self.deadline_s = deadline_s
        self._act: dict = {}        # (producer stage, mb) -> act on stage+1
        self._grad: dict = {}       # (consumer stage, mb) -> grad on stage

    def _await(self, value, what: str):
        if self.deadline_s is None:
            return value
        from repro.supervise.watchdog import wait_ready
        for leaf in jax.tree_util.tree_leaves(value):
            wait_ready(leaf, self.deadline_s, what)
        return value

    def send_act(self, stage: int, mb: int, value) -> None:
        """Stage ``stage``'s forward output for ``mb`` -> stage ``stage+1``
        (transfer issued NOW, ahead of consumption)."""
        self._act[(stage, mb)] = jax.device_put(value,
                                                self.places[stage + 1])

    def recv_act(self, stage: int, mb: int):
        """The boundary activation stage ``stage`` produced for ``mb``, as
        resident on stage ``stage+1`` (non-consuming read)."""
        return self._await(self._act[(stage, mb)],
                           f"boundary act {stage}->{stage + 1} mb{mb}")

    def evict_act(self, stage: int, mb: int) -> None:
        self._act.pop((stage, mb), None)

    def send_grad(self, stage: int, mb: int, value) -> None:
        """The cotangent for stage ``stage``'s output of ``mb`` (produced by
        stage ``stage+1``'s backward) -> stage ``stage``."""
        self._grad[(stage, mb)] = jax.device_put(value, self.places[stage])

    def recv_grad(self, stage: int, mb: int):
        return self._await(self._grad.pop((stage, mb)),
                           f"boundary grad {stage + 1}->{stage} mb{mb}")


# ---------------------------------------------------------------------------
# Engine
# ---------------------------------------------------------------------------

class PP1F1BEngine:
    """Multi-device 1F1B executor for the dense-arch candidate.

    One instance = one compiled pipeline: ``collect(params, batch)`` runs a
    full 1F1B training iteration (forward + backward + grad accumulation,
    NO optimizer step) and returns the merged reference-shaped trace, the
    reference-named global gradient tree (placed on the controller device)
    and the per-rank ``MergeReport``.
    """

    def __init__(self, model, ref_params, batch, pp_size: int,
                 n_microbatches: int, bugs=frozenset(),
                 dispatch: str = "concurrent",
                 boundary_deadline_s: float | None = None):
        cfg = model.cfg
        if cfg.arch_type != "dense":
            # homogeneous attn_mlp stacks only: stages with aux-producing
            # blocks (MoE) would need the per-stage aux losses communicated
            # to the loss stage, which this engine does not implement
            raise ValueError("the 1F1B engine covers dense arches only "
                             f"(got arch_type={cfg.arch_type!r})")
        if pp_size < 2:
            raise ValueError("the 1F1B pipeline needs pp >= 2 stages")
        if n_microbatches < 1:
            raise ValueError("need at least one microbatch")
        if not isinstance(ref_params.get("layers"), (list, tuple)):
            raise ValueError("1F1B partitions unstacked layer lists — "
                             "rebuild the model with scan_layers=False")
        B = int(np.shape(batch["tokens"])[0])
        if B % n_microbatches:
            raise ValueError(f"batch size {B} not divisible into "
                             f"{n_microbatches} microbatches")
        devs = jax.devices()
        if len(devs) < pp_size:
            raise RuntimeError(
                f"need {pp_size} devices for {pp_size} pipeline stages, "
                f"have {len(devs)} — run under "
                f"XLA_FLAGS=--xla_force_host_platform_device_count={pp_size}")
        self.model, self.cfg = model, cfg
        self.bugs = frozenset(bugs)
        self.pp, self.M = pp_size, n_microbatches
        self.mb_size = B // n_microbatches
        self.tied = cfg.tie_embeddings
        if dispatch not in ("concurrent", "ordered"):
            raise ValueError(f"unknown dispatch mode {dispatch!r}")
        self.dispatch = dispatch
        # optional per-recv deadline on stage-boundary transfers: a dead
        # producer becomes a loud BoundaryTimeout, not an infinite stall
        self.boundary_deadline_s = boundary_deadline_s
        self.stages = stage_division(cfg.n_layers, pp_size, self.bugs)
        self.tables = stage_tables(cfg.n_layers, pp_size, self.bugs)
        self.streams = [stage_op_stream(pp_size, s, n_microbatches)
                        for s in range(pp_size)]
        self.schedule = schedule_1f1b(pp_size, n_microbatches)
        self._plan: MergePlan | None = None
        self.meshes = [Mesh(np.array(devs[s:s + 1]), ("stage",))
                       for s in range(pp_size)]
        self.places = [NamedSharding(m, P()) for m in self.meshes]
        self.home = devs[0]     # controller: merged trace + optimizer step

        # tap discovery (chained per-stage eval_shape) + once-jitted steps
        sds = lambda v: jax.ShapeDtypeStruct(tuple(np.shape(v)),  # noqa: E731
                                             jnp.result_type(v))
        mb_sds = {k: jax.ShapeDtypeStruct(
            (self.mb_size,) + tuple(np.shape(v))[1:], jnp.result_type(v))
            for k, v in batch.items()}
        self._fwd, self._bwd = [], []
        self._probes, self._orders = [], []
        h_sds = None
        for s in range(pp_size):
            p_sds = jax.tree.map(sds, self._slice_params(ref_params, s))
            out_sds, taps_sds, order = self._discover(s, p_sds, h_sds,
                                                      mb_sds)
            self._probes.append({k: jax.device_put(v, self.places[s])
                                 for k, v in _make_probes(taps_sds, None,
                                                          True).items()})
            self._orders.append(order)
            self._fwd.append(jax.jit(self._fwd_fn(s)))
            self._bwd.append(jax.jit(self._bwd_fn(s)))
            if s < pp_size - 1:
                h_sds = out_sds

    # ---- partitioning ------------------------------------------------------
    def _slice_params(self, params, s: int) -> dict:
        """Stage ``s``'s rank-local parameter tree (stage-LOCAL layer list;
        embedding replicated on first/last stage when tied)."""
        start, end = self.stages[s]
        p = {"layers": [params["layers"][i] for i in range(start, end)]}
        if s == 0:
            p["embedding"] = params["embedding"]
        if s == self.pp - 1:
            p["final_norm"] = params["final_norm"]
            if self.tied:
                p["embedding"] = params["embedding"]
            else:
                p["lm_head"] = params["lm_head"]
        return p

    # ---- stage computation -------------------------------------------------
    def _apply(self, s: int, p, h, mb, ctx):
        """Stage forward with stage-LOCAL tap names: embeds on stage 0,
        applies the local layer slice, finishes with norm + loss on the
        last stage (loss = per-microbatch mean CE, so the mean over equal
        microbatches equals the reference full-batch loss)."""
        from repro.models.layers import _logits, cross_entropy, rmsnorm
        cfg = self.cfg
        if s == 0:
            h = self.model.embed(p, mb, ctx)
        # dense attn_mlp blocks have zero aux loss (enforced in __init__),
        # so only the loss stage needs to carry it
        aux = jnp.zeros((), jnp.float32)
        for local in range(len(self.tables[s])):
            with ctx.scope(f"layers.{local}"):
                h, a, _ = block_apply(p["layers"][local], cfg, "attn_mlp",
                                      h, ctx)
            if s == self.pp - 1:
                aux = aux + a
        if s < self.pp - 1:
            return h
        h = rmsnorm(p["final_norm"], h)
        h = ctx.tap("final_norm_out", h)
        e = (p["embedding"]["word_embeddings"] if self.tied
             else p["lm_head"])
        return cross_entropy(_logits(h, e), mb["labels"]) + aux

    def _discover(self, s, p_sds, h_sds, mb_sds):
        order: list[str] = []

        def f(p, h, mb):
            ctx = TraceContext("collect")
            out = self._apply(s, p, h, mb, ctx)
            order.clear()
            order.extend(ctx.fwd.keys())
            return out, ctx.fwd

        out_sds, taps_sds = jax.eval_shape(f, p_sds, h_sds, mb_sds)
        return out_sds, taps_sds, list(order)

    def _fwd_fn(self, s: int):
        def fwd(p, h, mb, rew):
            ctx = TraceContext("rewrite" if rew else "collect", rewrites=rew)
            out = self._apply(s, p, h, mb, ctx)
            return out, ctx.fwd
        return fwd

    def _bwd_fn(self, s: int):
        """Backward op: recompute the stage forward from the stashed input
        inside ``jax.vjp`` (with the act-grad zero probes as primals), seed
        with the downstream cotangent, return (input grad, param grads,
        act grads)."""
        def bwd(p, h, mb, g, rew, pr):
            if s == 0:
                def fn(pp_, prr):
                    ctx = TraceContext("rewrite" if rew else "collect",
                                       probes=prr, rewrites=rew)
                    return self._apply(s, pp_, None, mb, ctx)
                _, vjp = jax.vjp(fn, p, pr)
                dp, dpr = vjp(g)
                return None, dp, dpr

            def fn(pp_, hh, prr):
                ctx = TraceContext("rewrite" if rew else "collect",
                                   probes=prr, rewrites=rew)
                return self._apply(s, pp_, hh, mb, ctx)
            _, vjp = jax.vjp(fn, p, h, pr)
            dp, dh, dpr = vjp(g)
            return dh, dp, dpr
        return bwd

    # ---- batch / rewrite plumbing ------------------------------------------
    def _split_batch(self, batch) -> list[dict]:
        bs = self.mb_size
        b = {k: jnp.asarray(v) for k, v in batch.items()}
        return [{k: v[m * bs:(m + 1) * bs] for k, v in b.items()}
                for m in range(self.M)]

    def _stage_rewrites(self, rewrites):
        """Canonical full-batch rewrites -> ``[stage][mb] -> {local: value}``
        (the inverse of the merger's renaming, sliced per microbatch)."""
        if not rewrites:
            return None
        bs = self.mb_size
        out = []
        for s in range(self.pp):
            per_mb = [dict() for _ in range(self.M)]
            for ln in self._orders[s]:
                cn = canonical_stage_name(ln, self.tables[s])
                if cn in rewrites:
                    v = jnp.asarray(rewrites[cn])
                    for m in range(self.M):
                        per_mb[m][ln] = jax.device_put(
                            v[m * bs:(m + 1) * bs], self.places[s])
            out.append(per_mb)
        return out

    # ---- the 1F1B iteration ------------------------------------------------
    def collect(self, params, batch, rewrites=None):
        """One full 1F1B training iteration.  Returns ``(merged_trace,
        grads_tree, merge_report)``; ``grads_tree`` is reference-named and
        placed on the controller device for the optimizer step.

        Per-stage ops are dispatched dependency-driven (each stage's next
        op launches as soon as its cross-stage input's device future
        exists), boundary transfers are issued at produce time through the
        ``BoundaryTransport`` seam, and the per-rank records are merged by
        the build-once ``MergePlan`` — all of it async dispatch; the host
        never blocks inside the iteration.
        """
        M, S = self.M, self.pp
        mbs = self._split_batch(batch)
        mb_first = [jax.device_put(mb, self.places[0]) for mb in mbs]
        mb_last = [jax.device_put(mb, self.places[-1]) for mb in mbs]
        rew = self._stage_rewrites(rewrites)
        ps = [jax.device_put(self._slice_params(params, s), self.places[s])
              for s in range(S)]
        cot = jax.device_put(jnp.float32(1.0 / M), self.places[-1])
        stale = "pp_stale_boundary" in self.bugs
        misorder = "pp_microbatch_order" in self.bugs

        tp = BoundaryTransport(self.places,
                               deadline_s=self.boundary_deadline_s)
        stash: list[dict] = [dict() for _ in range(S)]
        losses: list = [None] * M
        records: dict = {}             # (s, m, d) -> rank-local Trace

        def mb_arg(s, m):
            if s == 0:
                return mb_first[m]
            if s == S - 1:
                return mb_last[m]
            return None

        def run_op(d, s, m):
            r = rew[s][m] if rew else {}
            if d == "F":
                if s == 0:
                    h_in = None
                else:
                    # boundary recv: the stale-boundary bug re-reads the
                    # previous microbatch's recv slot
                    src = m - 1 if (stale and m > 0) else m
                    h_in = tp.recv_act(s - 1, src)
                out, taps = self._fwd[s](ps[s], h_in, mb_arg(s, m), r)
                stash[s][m] = h_in
                if s == S - 1:
                    losses[m] = out
                else:
                    # transfer to stage s+1 issued NOW — it overlaps this
                    # stage's (and every other stage's) in-flight compute
                    tp.send_act(s, m, out)
                if s > 0 and m > 0:
                    # recv-slot eviction: slot (s-1, k) feeds forward (s, k)
                    # and — under the stale-boundary bug — forward (s, k+1);
                    # once (s, m) ran, (s-1, m-1) is dead, so at most two
                    # slots live per stage pair
                    tp.evict_act(s - 1, m - 1)
                tr = Trace()
                tr.activations = dict(taps)
                tr.meta.update(stage=s, microbatch=m,
                               fwd_order=list(self._orders[s]))
            else:
                # the microbatch-order bug misindexes the activation stash
                # (and, on stage 0, the token microbatch it re-embeds)
                src = m + 1 if (misorder and (m + 1) in stash[s]) else m
                h_in = stash[s][src]
                mb_in = mb_arg(s, src if s == 0 else m)
                g = cot if s == S - 1 else tp.recv_grad(s, m)
                dh, dp, dpr = self._bwd[s](ps[s], h_in, mb_in, g, r,
                                           self._probes[s])
                del stash[s][m]
                if s > 0:
                    tp.send_grad(s - 1, m, dh)
                tr = Trace()
                tr.act_grads = dict(dpr)
                tr.param_grads = flatten_named(dp)
                tr.meta.update(stage=s, microbatch=m)
            records[(s, m, d)] = tr

        if self.dispatch == "ordered":
            for d, s, m in self.schedule:
                run_op(d, s, m)
        else:
            self._drive_concurrent(run_op)

        # canonical record order (driver-independent): the MergePlan
        # signature and the merged trace are identical either way
        rec_list = [(s, m, records[(s, m, d)])
                    for (s, m, d) in sorted(records,
                                            key=lambda k: (k[0], k[1], k[2]))]
        if self._plan is None:
            self._plan = MergePlan.build(rec_list, self.tables, M,
                                         place=self.home)
        merged, report = self._plan.execute(rec_list)
        stage_pg = self._plan.stage_param_grads
        if stage_pg is None:           # fell back (foreign record structure)
            stage_pg = {}
            for (s, m, d), tr in sorted(records.items()):
                if d != "B":
                    continue
                for n, g in tr.param_grads.raw_items():
                    g = jax.device_put(g, self.home)
                    key = (s, n)
                    stage_pg[key] = (stage_pg[key] + g if key in stage_pg
                                     else g)
        loss = losses[0]
        for m in range(1, M):
            loss = loss + losses[m]
        merged.loss = loss / M
        merged.meta["microbatches"] = M
        merged.meta["pp"] = S
        return merged, self._global_grads(params, stage_pg), report

    def _drive_concurrent(self, run_op):
        """Dependency-driven per-stage dispatch: launch each op the moment
        its cross-stage input's device future exists — no global
        clock-tick linearization, each stage runs as far ahead as its data
        allows.  Per-stage op order is exactly ``stage_op_stream``, so
        device execution (and with it every trace) is identical to the
        ordered drive."""
        walk_1f1b(self.streams, run_op)

    def _global_grads(self, params, stage_pg):
        """Per-stage accumulated grads ``{(stage, local name): leaf}`` (on
        the controller, courtesy of the merge plan's packed transfer) ->
        reference-named global tree.  Stage-local layer indices map to the
        EXECUTED global layers (a twice-executed layer's contributions sum,
        exactly like autodiff on the staged candidate); never-executed
        layers get zero grads; tied-embedding contributions from both
        pipeline ends are summed (the explicit tied-embedding reduction)."""
        named: dict = {}
        for (s, n), g in stage_pg.items():
            if n.startswith("layers."):
                start = self.stages[s][0]
                local, _, rest = n[len("layers."):].partition(".")
                tgt = f"layers.{start + int(local)}.{rest}"
            else:
                tgt = n
            named[tgt] = named[tgt] + g if tgt in named else g
        tpl = flatten_named(params)
        for n, v in tpl.items():
            if n not in named:
                named[n] = jnp.zeros(np.shape(v), jnp.result_type(v))
        return unflatten_named(named, params)


# ---------------------------------------------------------------------------
# Supervisor / harness entry points (the CandidateStep contract)
# ---------------------------------------------------------------------------

def make_pp1f1b_train_step(model, ref_params, opt, batch, pp_size: int,
                           microbatches: int, bugs=frozenset()):
    """Once-compiled stateful 1F1B candidate train step (supervisor
    contract): ``step(params, opt_state, batch) -> (Trace, new_params,
    new_opt_state)``.  The per-stage fwd/bwd jits and the optimizer update
    compile exactly once and are reused every supervised step and bisection
    replay."""
    eng = PP1F1BEngine(model, ref_params, batch, pp_size, microbatches,
                       bugs)
    upd = jax.jit(opt.update)

    def step(params, opt_state, b):
        tr, grads, _ = eng.collect(params, b)
        new_p, new_st, info = upd(params, grads, opt_state)
        tr.main_grads = flatten_named(info.main_grads)
        tr.params_post = flatten_named(new_p)
        tr.grad_norm = info.grad_norm
        return tr, new_p, new_st

    params0 = jax.tree.map(jnp.asarray, ref_params)
    return step, params0, opt.init(params0)


def make_pp1f1b_runner(model, params, pp_size: int, microbatches: int,
                       opt=None, opt_state=None, bugs=frozenset()):
    """``runner(batch, rewrites) -> Trace`` over the 1F1B engine — the
    rewrite-mode localization side of the candidate (engine built lazily
    from the first batch's shapes)."""
    eng = None

    def run(batch, rewrites=None) -> Trace:
        nonlocal eng
        if eng is None:
            eng = PP1F1BEngine(model, params, batch, pp_size, microbatches,
                               bugs)
        tr, grads, _ = eng.collect(params, batch, rewrites=rewrites)
        if opt is not None:
            st = opt_state if opt_state is not None else opt.init(params)
            new_p, _, info = opt.update(params, grads, st)
            tr.main_grads = flatten_named(info.main_grads)
            tr.params_post = flatten_named(new_p)
            tr.grad_norm = info.grad_norm
        return tr

    return run
