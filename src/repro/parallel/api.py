"""Candidate-runner builder: shard_map plumbing for the distributed GPT.

``make_candidate_runner`` turns (ArchConfig, ParallelConfig, reference
params) into a ``runner(batch, rewrites) -> Trace`` with the SAME canonical
tap names as the single-device reference — the distributed half of TTrace's
differential test.

Plumbing responsibilities:
  * build the ("dp","cp","tp") mesh and shard params/batch/probes per the
    generated annotations (the programmatic equivalent of the paper's Fig 2
    user annotations);
  * zigzag-permute sequence-dim inputs for context parallelism and
    un-permute collected taps back to logical order (paper Fig 6 layout);
  * two-phase tap discovery (shard_map needs out_specs before tracing);
  * post-backward gradient reductions over dp/cp/tp per tensor — the
    bug-injection site for the loss-scaling and missing-all-reduce bugs;
  * the optimizer step (plain AdamW or ZeRO-1) with main-grad and post-step
    parameter tracing.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.core.annotations import Annotations, ShardSpec
from repro.core.collector import Trace, flatten_named, unflatten_named
from repro.core.tap import TraceContext
from repro.parallel.gpt import parallel_gpt_loss
from repro.parallel.layers import permute_from_zigzag, permute_to_zigzag
from repro.parallel.zero import zero1_update

try:                               # jax >= 0.6: top-level, check_vma kwarg
    from jax import shard_map as _shard_map
    _SM_CHECK_KW = "check_vma"
except ImportError:                # jax 0.4.x: experimental, check_rep kwarg
    from jax.experimental.shard_map import shard_map as _shard_map
    _SM_CHECK_KW = "check_rep"


def shard_map_unchecked(f, mesh, in_specs, out_specs):
    """shard_map with replication/VMA checking off, across jax versions."""
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **{_SM_CHECK_KW: False})


MESH_AXES = {"dp": "dp", "cp": "cp", "tp": "tp", "sp": "tp"}


@dataclass(frozen=True)
class ParallelConfig:
    dp: int = 1
    cp: int = 1
    tp: int = 1
    sp: bool = False
    zero1: bool = False
    pp: int = 1                  # pipeline candidate (parallel.pp / pp1f1b)
    pp_schedule: str = "staged"  # staged (single-controller) | 1f1b
    microbatches: int = 1        # 1F1B microbatch count
    fp8: Optional[str] = None    # FP8 recipe: global | per_tensor | tile128
    bugs: frozenset = frozenset()

    @property
    def n_devices(self):
        # staged pp and fp8 are single-controller candidate recipes — they
        # model semantics (stage division, quantization), not placement;
        # the 1F1B engine places one pipeline stage per device
        base = self.dp * self.cp * self.tp
        if self.pp > 1 and self.pp_schedule == "1f1b":
            return base * self.pp
        return base

    @property
    def features(self) -> set:
        f = set()
        if self.dp > 1: f.add("dp")
        if self.cp > 1: f.add("cp")
        if self.tp > 1: f.add("tp")
        if self.sp: f.add("sp")
        if self.zero1: f.add("zero1")
        if self.pp > 1: f.add("pp")
        if self.pp > 1 and self.pp_schedule == "1f1b": f.add("1f1b")
        if self.fp8: f.add("fp8")
        return f

    @property
    def recipe_kind(self) -> str:
        """Which candidate implementation drives this config."""
        if self.fp8 and self.pp > 1:
            raise ValueError("pp + fp8 in one candidate is not supported")
        if self.pp_schedule not in ("staged", "1f1b"):
            raise ValueError(f"unknown pp_schedule {self.pp_schedule!r}")
        if self.fp8:
            return "fp8"
        if self.pp > 1:
            return "pp_1f1b" if self.pp_schedule == "1f1b" else "pp"
        return "shard_map"


def spare_host_device(pcfg: ParallelConfig):
    """The last device OUTSIDE the candidate's placement footprint, or None.

    Candidate recipes place on the first ``pcfg.n_devices`` devices (the
    shard_map mesh, the 1F1B per-stage submeshes, device 0 for the
    single-controller recipes), so the last device — when one is spare —
    forms a disjoint set the supervisor's reference step can run on
    concurrently."""
    devs = jax.devices()
    return devs[-1] if len(devs) > pcfg.n_devices else None


def make_device_mesh(pcfg: ParallelConfig) -> Mesh:
    # the shard_map mesh covers the dp/cp/tp axes only — the 1F1B engine's
    # per-stage devices (the pp factor of n_devices) never join this mesh
    n = pcfg.dp * pcfg.cp * pcfg.tp
    devs = jax.devices()
    if len(devs) < n:
        raise RuntimeError(
            f"need {n} devices, have {len(devs)} — run under "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={n}")
    arr = np.array(devs[:n]).reshape(pcfg.dp, pcfg.cp, pcfg.tp)
    return Mesh(arr, ("dp", "cp", "tp"))


# ---------------------------------------------------------------------------
# Annotation generation (what a user would write by hand, paper Fig 2)
# ---------------------------------------------------------------------------

def build_annotations(cfg: ArchConfig, pcfg: ParallelConfig) -> Annotations:
    sp = pcfg.sp
    cp = pcfg.cp > 1
    seqspec = dict(cp_dim=1 if cp else None, cp_mode="zigzag",
                   sp_dim=1 if sp else None, dp_dim=0)
    params = {
        "embedding.word_embeddings": {"tp_dim": 0},
        "lm_head": {"tp_dim": 0},
        "layers.*.self_attention.linear_qkv.w": {"tp_dim": 1},
        "layers.*.self_attention.linear_qkv.b": {"tp_dim": 0},
        "layers.*.self_attention.linear_proj.w": {"tp_dim": 0},
        "layers.*.mlp.gate.w": {"tp_dim": 1},
        "layers.*.mlp.up.w": {"tp_dim": 1},
        "layers.*.mlp.down.w": {"tp_dim": 0},
        "layers.*.mlp.experts.gate": {"tp_dim": 0},   # expert dim
        "layers.*.mlp.experts.up": {"tp_dim": 0},
        "layers.*.mlp.experts.down": {"tp_dim": 0},
    }
    acts = {
        "embedding/output": seqspec,
        "layers.*.self_attention/input": seqspec,
        "layers.*.self_attention/core_attn_out":
            {"tp_dim": -1, "cp_dim": 1 if cp else None, "cp_mode": "zigzag",
             "dp_dim": 0},
        "layers.*.self_attention/output": seqspec,
        "layers.*.mlp/input": seqspec,
        "layers.*.mlp/output": seqspec,
        "layers.*.mlp/router_logits":
            {"cp_dim": 1 if cp else None, "cp_mode": "zigzag", "dp_dim": 0},
        "final_norm_out": seqspec,
    }
    return Annotations.from_dict({"params": params, "acts": acts})


def spec_to_pspec(spec: ShardSpec, ndim: int, pcfg: ParallelConfig) -> P:
    """ShardSpec -> PartitionSpec on the ("dp","cp","tp") mesh."""
    dims: dict[int, list[str]] = {}

    def add(axis, mesh_axis, active):
        d = spec.dim_for(axis)
        if d is None or not active:
            return
        dims.setdefault(d % ndim, []).append(mesh_axis)

    # outer-to-inner order must match annotations.AXES: dp, ep, cp, tp, sp
    add("dp", "dp", pcfg.dp > 1)
    add("ep", "tp", pcfg.tp > 1)
    add("cp", "cp", pcfg.cp > 1)
    add("tp", "tp", pcfg.tp > 1)
    add("sp", "tp", pcfg.sp)
    entries = []
    for i in range(ndim):
        ax = dims.get(i, [])
        entries.append(None if not ax else (ax[0] if len(ax) == 1
                                            else tuple(ax)))
    while entries and entries[-1] is None:
        entries.pop()
    return P(*entries)


def sizes_coords(pcfg: ParallelConfig):
    return {"dp": pcfg.dp, "cp": pcfg.cp, "tp": pcfg.tp,
            "sp": pcfg.tp if pcfg.sp else 1}


# ---------------------------------------------------------------------------
# Gradient reduction rules (the bug surface)
# ---------------------------------------------------------------------------

def _needs_tp_reduce(name: str, pcfg: ParallelConfig) -> bool:
    if name.endswith("q_norm") or name.endswith("k_norm"):
        return pcfg.tp > 1          # head-sharded compute, always partial
    if name.endswith("router"):
        # expert-parallel: each rank backprops only its local experts'
        # combine weights into the (replicated) router — the grads are
        # partial and must be all-reduced over the EP (= tp) group.  This is
        # the sync Megatron's bug 6 family is about.
        return pcfg.tp > 1
    norm_like = name.endswith(("input_norm", "post_attn_norm", "final_norm"))
    return pcfg.sp and pcfg.tp > 1 and norm_like


def reduce_param_grads(pg_named: dict, pcfg: ParallelConfig, bugs):
    out = {}
    for name, g in pg_named.items():
        if pcfg.dp > 1:
            g = jax.lax.psum(g, "dp")
            if "dp_wrong_loss_scale" not in bugs:
                g = g / pcfg.dp
        if pcfg.cp > 1:
            skip_cp = ("tp_cp_wrong_norm_grad" in bugs
                       and name.endswith("input_norm") and pcfg.tp > 1)
            if skip_cp:
                from repro.parallel.layers import one_rank
                g = one_rank(g, "cp")   # per-rank partial, silently wrong
            else:
                g = jax.lax.psum(g, "cp")
                if "cp_wrong_loss_scale" not in bugs:
                    g = g / pcfg.cp
        if _needs_tp_reduce(name, pcfg):
            skip = (("sp_layernorm_not_synced" in bugs
                     and name.endswith("post_attn_norm"))
                    or ("tp_missing_grad_allreduce" in bugs
                        and name.endswith("input_norm")))
            if skip:
                from repro.parallel.layers import one_rank
                g = one_rank(g, "tp")   # per-rank partial, silently wrong
            else:
                g = jax.lax.psum(g, "tp")
        out[name] = g
    return out


def reduce_act_grads(ag: dict, ann: Annotations, pcfg: ParallelConfig, bugs):
    """Activation-gradient (probe) scaling.  The tp accumulation is already
    handled by the f/g conjugate operators inside the layers; what remains is
    the dp/cp loss averaging — the same scale factors whose bugs (3, 4) the
    paper catalogues."""
    out = {}
    for name, g in ag.items():
        if pcfg.tp > 1 and name.endswith("router_logits"):
            # dispatch + (tp-partialized) aux contributions sum over tp
            g = jax.lax.psum(g, "tp")
        if pcfg.dp > 1 and "dp_wrong_loss_scale" not in bugs:
            g = g / pcfg.dp
        if pcfg.cp > 1 and "cp_wrong_loss_scale" not in bugs:
            g = g / pcfg.cp
        out[name] = g
    return out


# ---------------------------------------------------------------------------
# Compiled-step caches
# ---------------------------------------------------------------------------
#
# make_candidate_runner used to rebuild (and re-trace) a fresh shard_map per
# call; every TTrace check paid full tracing + compilation again.  Both the
# tap-discovery result and the jitted step are pure functions of
# (ArchConfig, ParallelConfig, input signature), so they are cached at module
# level keyed on exactly that — repeated runner builds (and the supervisor's
# bisection replays) reuse one compiled step per side.

_TAP_CACHE: dict = {}     # (cfg, pcfg, psig, bsig) -> (names, ti)
_STEP_CACHE: dict = {}    # + (probe names, rewrite names, jit) -> callable


def _abstract_sig(named: dict) -> tuple:
    return tuple((n, tuple(np.shape(v)), str(jnp.result_type(v)))
                 for n, v in sorted(named.items()))


def clear_step_cache():
    """Drop cached compiled candidate steps (tests / mesh reconfiguration)."""
    _TAP_CACHE.clear()
    _STEP_CACHE.clear()


# ---------------------------------------------------------------------------
# Recipe dispatch (pp / fp8 candidates share the supervisor contract)
# ---------------------------------------------------------------------------

def _check_recipe_pcfg(cfg: ArchConfig, pcfg: ParallelConfig) -> None:
    if pcfg.dp * pcfg.cp * pcfg.tp != 1 or pcfg.zero1 or pcfg.sp:
        raise ValueError(
            f"the {pcfg.recipe_kind} candidate cannot combine with "
            f"dp/cp/tp/zero1 (got {pcfg})")
    if pcfg.microbatches > 1 and pcfg.recipe_kind != "pp_1f1b":
        # only the 1F1B engine executes microbatches; anywhere else the
        # flag would be a silent no-op
        raise ValueError(
            f"microbatches={pcfg.microbatches} applies to the 1F1B "
            f"pipeline only (recipe {pcfg.recipe_kind})")
    if cfg.arch_type != "dense":
        # fp8 quantizes the dense MLP matmuls only (MoE expert matmuls are
        # a ROADMAP follow-up) and the pp losses partition homogeneous
        # attn_mlp stacks; running other arches would be a silent no-op —
        # the injected bug never expresses and a clean PASS means nothing
        raise ValueError(
            f"the {pcfg.recipe_kind} candidate covers dense arches only "
            f"(got arch_type={cfg.arch_type!r})")


def _recipe_runner(cfg: ArchConfig, pcfg: ParallelConfig, ref_params,
                   opt=None, opt_state=None):
    _check_recipe_pcfg(cfg, pcfg)
    from repro.models.model import Model
    model = Model(cfg)
    if pcfg.recipe_kind == "pp":
        from repro.parallel.pp import make_pp_runner
        return make_pp_runner(model, ref_params, pcfg.pp, opt=opt,
                              opt_state=opt_state, bugs=pcfg.bugs)
    if pcfg.recipe_kind == "pp_1f1b":
        from repro.parallel.pp1f1b import make_pp1f1b_runner
        return make_pp1f1b_runner(model, ref_params, pcfg.pp,
                                  pcfg.microbatches, opt=opt,
                                  opt_state=opt_state, bugs=pcfg.bugs)
    from repro.precision.fp8 import make_fp8_runner
    return make_fp8_runner(model, ref_params, pcfg.fp8, opt=opt,
                           opt_state=opt_state, bugs=pcfg.bugs)


def _recipe_train_step(cfg: ArchConfig, pcfg: ParallelConfig, ref_params,
                       opt, batch):
    _check_recipe_pcfg(cfg, pcfg)
    from repro.models.model import Model
    model = Model(cfg)
    if pcfg.recipe_kind == "pp":
        from repro.parallel.pp import make_pp_train_step
        return make_pp_train_step(model, ref_params, opt, batch, pcfg.pp,
                                  bugs=pcfg.bugs)
    if pcfg.recipe_kind == "pp_1f1b":
        from repro.parallel.pp1f1b import make_pp1f1b_train_step
        return make_pp1f1b_train_step(model, ref_params, opt, batch,
                                      pcfg.pp, pcfg.microbatches,
                                      bugs=pcfg.bugs)
    from repro.precision.fp8 import make_fp8_train_step
    return make_fp8_train_step(model, ref_params, opt, batch, pcfg.fp8,
                               bugs=pcfg.bugs)


# ---------------------------------------------------------------------------
# Runner
# ---------------------------------------------------------------------------

def qkv_permutation(cfg: ArchConfig, tp: int) -> np.ndarray:
    """Column permutation mapping the reference fused-QKV layout [Q|K|V] to
    the tensor-parallel layout [q_0|k_0|v_0 | q_1|k_1|v_1 | ...] so that a
    contiguous tp shard holds its own heads' q, k and v.

    This is the paper's "mapping of semantics" problem in miniature: the
    candidate framework stores the same logical parameter in a different
    physical layout, and the tensor canonical mapping must undo it."""
    H, Hkv, D = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    q = np.arange(H * D).reshape(tp, -1)
    k = H * D + np.arange(Hkv * D).reshape(tp, -1)
    v = (H + Hkv) * D + np.arange(Hkv * D).reshape(tp, -1)
    return np.concatenate([np.concatenate([q[r], k[r], v[r]])
                           for r in range(tp)])


def layout_maps(cfg: ArchConfig, tp: int):
    """``(to_candidate, from_candidate)`` leaf mappers over the QKV layout
    permutation — the single source of the reference<->candidate parameter
    layout for the one-shot runner AND the supervisor's train step."""
    perm = qkv_permutation(cfg, tp)
    inv_perm = np.argsort(perm)

    def to_candidate(name, leaf):
        if name.endswith("linear_qkv.w"):
            return leaf[:, perm]
        if name.endswith("linear_qkv.b"):
            return leaf[perm]
        return leaf

    def from_candidate(name, leaf):
        if name.endswith("linear_qkv.w"):
            return leaf[:, inv_perm]
        if name.endswith("linear_qkv.b"):
            return leaf[inv_perm]
        return leaf

    return to_candidate, from_candidate


class _Plumbing:
    """Everything derived from (cfg, pcfg, params structure) that both
    candidate step builders share: mesh, annotations, layout mappers,
    partition specs, the shard_map body, and the zigzag un-permute."""

    def __init__(self, cfg: ArchConfig, pcfg: ParallelConfig,
                 ref_params: dict):
        self.cfg, self.pcfg = cfg, pcfg
        self.mesh = make_device_mesh(pcfg)
        self.ann = build_annotations(cfg, pcfg)
        self.to_cand, self.from_cand = layout_maps(cfg, pcfg.tp)
        # the QKV permutation reorders columns but never changes shape, so
        # candidate-layout abstract shapes == reference shapes
        named = flatten_named(ref_params)
        self.param_shapes = {n: jax.ShapeDtypeStruct(tuple(l.shape),
                                                     jnp.result_type(l))
                             for n, l in named.items()}
        self.psig = _abstract_sig(self.param_shapes)
        self.param_pspecs = {
            n: spec_to_pspec(self.ann.param_spec(n), l.ndim, pcfg)
            for n, l in self.param_shapes.items()}
        self.param_specs_tree = unflatten_named(dict(self.param_pspecs),
                                                ref_params)
        self.params_sds = unflatten_named(dict(self.param_shapes),
                                          ref_params)
        bspec = P("dp" if pcfg.dp > 1 else None,
                  "cp" if pcfg.cp > 1 else None)
        self.batch_spec = {"tokens": bspec, "labels": bspec}
        self.loss_axes = tuple(a for a, n in (("dp", pcfg.dp),
                                              ("cp", pcfg.cp)) if n > 1)

    def body(self, p, bb, probes, rew):
        """shard_map body: traced forward + backward + grad reductions."""
        cfg, pcfg, bugs = self.cfg, self.pcfg, self.pcfg.bugs

        def local_loss(pp, pr):
            ctx = TraceContext("rewrite" if rew else "collect",
                               probes=pr, rewrites=rew or {})
            gloss, rloss = parallel_gpt_loss(pp, bb, cfg, pcfg.sp, bugs, ctx)
            return gloss, (ctx.fwd, rloss)
        (_, (taps, rloss)), (pgt, ag) = jax.value_and_grad(
            local_loss, argnums=(0, 1), has_aux=True)(p, probes)
        pg = flatten_named(pgt)
        pg = reduce_param_grads(pg, pcfg, bugs)
        ag = reduce_act_grads(ag, self.ann, pcfg, bugs)
        loss = rloss
        if self.loss_axes:
            loss = jax.lax.psum(loss, self.loss_axes) / (pcfg.dp * pcfg.cp)
        return loss, taps, unflatten_named(pg, pgt), ag

    def taps_for(self, batch_abstract: dict):
        """Cached tap discovery for one batch signature: returns
        ``(tap_key, names, ti, act_pspecs, probes, probe_specs)``.
        Discovery is a full abstract trace of the forward — cached at module
        level so repeated runner builds and supervisor replays skip it."""
        cfg, pcfg = self.cfg, self.pcfg
        b_sds = {k: jax.ShapeDtypeStruct(tuple(np.shape(v)),
                                         jnp.result_type(v))
                 for k, v in batch_abstract.items()}
        tap_key = (cfg, pcfg, self.psig, _abstract_sig(b_sds))
        cached = _TAP_CACHE.get(tap_key)
        if cached is None:
            bugs = pcfg.bugs
            ti = {}

            def body_d(p, bb):
                ctx = TraceContext("collect")
                parallel_gpt_loss(p, bb, cfg, pcfg.sp, bugs, ctx)[0]
                ti.clear()
                ti.update({k: (v.shape, v.dtype)
                           for k, v in ctx.fwd.items()})
                return jnp.zeros(())
            jax.eval_shape(shard_map_unchecked(
                body_d, mesh=self.mesh,
                in_specs=(self.param_specs_tree, self.batch_spec),
                out_specs=P()), self.params_sds, b_sds)
            cached = _TAP_CACHE[tap_key] = (list(ti), ti)
        names, ti = cached
        pspecs = {n: spec_to_pspec(self.ann.act_spec(n), len(ti[n][0]), pcfg)
                  for n in names}
        szs = sizes_coords(pcfg)

        def gshape(n):
            shape = list(ti[n][0])
            spec = self.ann.act_spec(n)
            for ax in ("dp", "cp", "tp", "sp"):
                d = spec.dim_for(ax)
                if d is not None and szs.get(ax, 1) > 1:
                    shape[d % len(shape)] *= szs[ax]
            return tuple(shape)

        probes = {n: jnp.zeros(gshape(n), jnp.float32) for n in names
                  if jnp.issubdtype(ti[n][1], jnp.floating)}
        probe_specs = {n: pspecs[n] for n in probes}
        return tap_key, names, ti, pspecs, probes, probe_specs

    def cached_shard_map(self, tap_key, pspecs, probe_specs, rew_specs,
                        probes, jit: bool):
        """The compiled (or raw) shard-mapped step for one signature."""
        step_key = tap_key + (tuple(probes), tuple(sorted(rew_specs)),
                              bool(jit))
        fn = _STEP_CACHE.get(step_key)
        if fn is None:
            sm = shard_map_unchecked(
                self.body, mesh=self.mesh,
                in_specs=(self.param_specs_tree, self.batch_spec,
                          probe_specs, rew_specs),
                out_specs=(P(), pspecs, self.param_specs_tree,
                           {n: pspecs[n] for n in probes}))
            fn = _STEP_CACHE[step_key] = jax.jit(sm) if jit else sm
        return fn

    def unzig(self, n, x):
        spec = self.ann.act_spec(n)
        if self.pcfg.cp > 1 and spec.cp_dim is not None:
            return permute_from_zigzag(x, self.pcfg.cp,
                                       spec.cp_dim % x.ndim)
        return x

    def zigzag_batch(self, batch: dict) -> dict:
        out = {}
        for k in ("tokens", "labels"):
            v = jnp.asarray(batch[k])
            if self.pcfg.cp > 1:
                v = permute_to_zigzag(v, self.pcfg.cp, 1)
            out[k] = v
        return out


def make_candidate_runner(cfg: ArchConfig, pcfg: ParallelConfig,
                          ref_params: dict, opt=None, opt_state=None,
                          jit: bool = True):
    """Build ``runner(batch, rewrites) -> Trace`` for the candidate recipe:
    the shard_map distributed GPT, or (dispatching on ``pcfg``) the staged
    pipeline / FP8 candidates."""
    if pcfg.recipe_kind != "shard_map":
        return _recipe_runner(cfg, pcfg, ref_params, opt, opt_state)
    pl = _Plumbing(cfg, pcfg, ref_params)
    bugs = pcfg.bugs

    # shard the (layout-mapped) reference params onto the mesh
    sharded = {}
    for name, leaf in flatten_named(ref_params).items():
        sh = NamedSharding(pl.mesh, pl.param_pspecs[name])
        sharded[name] = jax.device_put(pl.to_cand(name, leaf), sh)
    params = unflatten_named(sharded, ref_params)

    def prep_batch(batch):
        return {k: jax.device_put(v, NamedSharding(pl.mesh,
                                                   pl.batch_spec[k]))
                for k, v in pl.zigzag_batch(batch).items()}

    def _run(batch, rewrites=None) -> Trace:
        b = prep_batch(batch)
        tap_key, names, ti, pspecs, probes, probe_specs = pl.taps_for(b)
        rew_in = {}
        if rewrites:
            for n, v in rewrites.items():
                if n not in names:
                    continue
                v = jnp.asarray(v)
                spec = pl.ann.act_spec(n)
                if pcfg.cp > 1 and spec.cp_dim is not None:
                    v = permute_to_zigzag(v, pcfg.cp, spec.cp_dim % v.ndim)
                rew_in[n] = jax.device_put(
                    v, NamedSharding(pl.mesh, pspecs[n]))
        rew_specs = {n: pspecs[n] for n in rew_in}

        fn = pl.cached_shard_map(tap_key, pspecs, probe_specs, rew_specs,
                                 probes, jit)
        loss, taps, pgt, ag = fn(params, b, probes, rew_in)

        tr = Trace()
        tr.loss = float(loss)
        # leaves stay device-resident jax.Arrays — the batched checker reads
        # them in place and only reduction scalars reach the host
        tr.activations = {n: pl.unzig(n, taps[n]) for n in names}
        tr.act_grads = {n: pl.unzig(n, ag[n]) for n in names if n in ag}
        pg_named = {k: pl.from_cand(k, v)
                    for k, v in flatten_named(pgt).items()}
        tr.param_grads = dict(pg_named)
        tr.meta["fwd_order"] = names
        tr.meta["annotations"] = pl.ann
        tr.meta["pcfg"] = pcfg

        if opt is not None:
            st = opt_state if opt_state is not None else opt.init(ref_params)
            grads_tree = unflatten_named(
                {k: jnp.asarray(v) for k, v in pg_named.items()}, ref_params)
            if pcfg.zero1:
                new_p, _, info = zero1_update(opt, ref_params, grads_tree,
                                              st, pcfg.dp, bugs)
            else:
                new_p, _, info = opt.update(ref_params, grads_tree, st)
            tr.main_grads = flatten_named(info.main_grads)
            tr.params_post = flatten_named(new_p)
            tr.grad_norm = float(info.grad_norm)
        return tr

    return _run


# ---------------------------------------------------------------------------
# Stateful candidate train step (the supervisor's lockstep contract)
# ---------------------------------------------------------------------------

def make_candidate_train_step(cfg: ArchConfig, pcfg: ParallelConfig,
                              ref_params: dict, opt, batch):
    """Once-compiled FULL candidate train step with trace collection.

    ``make_candidate_runner`` is stateless — it re-shards the reference
    params every call and applies the optimizer step eagerly on the host.
    The streaming supervisor instead threads the candidate's own
    (params, opt_state) through N steps, so the whole step — layout mapping,
    shard_map forward/backward, gradient reductions, the (possibly buggy
    ZeRO) optimizer update and the zigzag un-permutation of the taps — is
    fused into ONE jitted callable, compiled once against the template
    ``batch`` shapes.

    Persistent state lives in REFERENCE layout (fused-QKV order, host
    default placement); the step maps it to the candidate layout and mesh
    sharding internally.  Returns ``(step, params0, opt_state0)`` with
    ``step(params, opt_state, batch) -> (Trace, new_params, new_opt_state)``.
    Trace sections stay device-resident; loss/grad_norm stay device scalars.

    Dispatches on ``pcfg.recipe_kind``: the pipeline-parallel and FP8
    candidates return their own once-compiled steps under the same contract
    (``parallel.pp`` / ``precision.fp8``).
    """
    if pcfg.recipe_kind != "shard_map":
        return _recipe_train_step(cfg, pcfg, ref_params, opt, batch)
    pl = _Plumbing(cfg, pcfg, ref_params)
    bugs = pcfg.bugs
    tap_key, names, ti, pspecs, probes, probe_specs = pl.taps_for(
        {k: batch[k] for k in ("tokens", "labels")})
    # raw (unjitted) shard_map — jitted once below as part of the full step
    sm = pl.cached_shard_map(tap_key, pspecs, probe_specs, {}, probes,
                             jit=False)

    def _step(params, opt_state, b, pr):
        cand = unflatten_named(
            {n: pl.to_cand(n, l) for n, l in flatten_named(params).items()},
            params)
        loss, taps, pgt, ag = sm(cand, b, pr, {})
        pg_named = {k: pl.from_cand(k, v)
                    for k, v in flatten_named(pgt).items()}
        grads_tree = unflatten_named(pg_named, params)
        if pcfg.zero1:
            new_p, new_st, info = zero1_update(opt, params, grads_tree,
                                               opt_state, pcfg.dp, bugs)
        else:
            new_p, new_st, info = opt.update(params, grads_tree, opt_state)
        return (loss, taps, pg_named, ag, flatten_named(info.main_grads),
                info.grad_norm, new_p, new_st)

    step_c = jax.jit(_step)

    def step(params, opt_state, batch) -> tuple[Trace, dict, dict]:
        # zigzag (un)permutation stays EAGER on both sides of the jitted
        # step: global split/concat/reshape of sharded leaves inside jit
        # miscompiles under GSPMD on this jax line (see zero1_update), and
        # the eager path is the one the one-shot runner already proves out.
        # cp == 1 makes both transforms the identity.
        bb = pl.zigzag_batch(batch)
        (loss, taps, pg_named, ag, main_grads, grad_norm,
         new_p, new_st) = step_c(params, opt_state, bb, probes)
        taps = {n: pl.unzig(n, taps[n]) for n in taps}
        ag = {n: pl.unzig(n, ag[n]) for n in ag}
        tr = Trace()
        tr.loss = loss
        tr.grad_norm = grad_norm
        tr.activations = {n: taps[n] for n in names}
        tr.act_grads = {n: ag[n] for n in names if n in ag}
        tr.param_grads = dict(pg_named)
        tr.main_grads = main_grads
        tr.params_post = flatten_named(new_p)
        tr.meta["fwd_order"] = list(names)
        tr.meta["annotations"] = pl.ann
        tr.meta["pcfg"] = pcfg
        return tr, new_p, new_st

    # commit the persistent state to the mesh (replicated): the step's
    # shard_map re-shards internally, jit accepts mesh-committed inputs, and
    # checkpoint restores (which inherit the template's sharding) come back
    # mesh-compatible for bisection replay
    rep = NamedSharding(pl.mesh, P())
    params0 = jax.device_put(jax.tree.map(jnp.asarray, ref_params), rep)
    state0 = jax.device_put(opt.init(params0), rep)
    return step, params0, state0


# ---------------------------------------------------------------------------
# Plain (trace-free) distributed training step — used by the loss-curve
# blindness demo (paper Fig 1) and the detection-latency benchmark (§6.4):
# the "naive debugging practice" trains the candidate and watches the loss.
# ---------------------------------------------------------------------------

def make_plain_train_step(cfg: ArchConfig, pcfg: ParallelConfig,
                          ref_params: dict, opt):
    """Returns (step_fn, params0, opt_state0): a jitted full train step of
    the distributed candidate (bugs included) without any tracing."""
    mesh = make_device_mesh(pcfg)
    ann = build_annotations(cfg, pcfg)
    bugs = pcfg.bugs
    perm = qkv_permutation(cfg, pcfg.tp)
    inv_perm = np.argsort(perm)

    def to_cand(name, leaf):
        if name.endswith("linear_qkv.w"):
            return leaf[:, perm]
        if name.endswith("linear_qkv.b"):
            return leaf[perm]
        return leaf

    named = {n: to_cand(n, l) for n, l in flatten_named(ref_params).items()}
    pspecs = {n: spec_to_pspec(ann.param_spec(n), l.ndim, pcfg)
              for n, l in named.items()}
    params = unflatten_named(
        {n: jax.device_put(l, NamedSharding(mesh, pspecs[n]))
         for n, l in named.items()}, ref_params)
    spec_tree = unflatten_named(pspecs, ref_params)
    bspec = P("dp" if pcfg.dp > 1 else None, "cp" if pcfg.cp > 1 else None)
    loss_axes = tuple(a for a, n in (("dp", pcfg.dp), ("cp", pcfg.cp))
                      if n > 1)

    def body(p, b):
        gloss, rloss = parallel_gpt_loss(p, b, cfg, pcfg.sp, bugs, None)
        grads = jax.grad(lambda pp: parallel_gpt_loss(
            pp, b, cfg, pcfg.sp, bugs, None)[0])(p)
        pg = reduce_param_grads(flatten_named(grads), pcfg, bugs)
        if loss_axes:
            rloss = jax.lax.psum(rloss, loss_axes) / (pcfg.dp * pcfg.cp)
        return rloss, unflatten_named(pg, grads)

    sm = shard_map_unchecked(body, mesh=mesh,
                             in_specs=(spec_tree, {"tokens": bspec,
                                                   "labels": bspec}),
                             out_specs=(P(), spec_tree))

    opt_state = opt.init(params)

    @jax.jit
    def step(params, opt_state, batch):
        loss, grads = sm(params, batch)
        params, opt_state, info = opt.update(params, grads, opt_state)
        return params, opt_state, loss

    def prep(batch):
        out = {}
        for k in ("tokens", "labels"):
            v = jnp.asarray(batch[k])
            if pcfg.cp > 1:
                v = permute_to_zigzag(v, pcfg.cp, 1)
            out[k] = jax.device_put(v, NamedSharding(mesh, bspec))
        return out

    return step, prep, params, opt_state
