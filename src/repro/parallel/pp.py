"""Pipeline-parallel staged execution + the stage-division silent bug.

Single-controller JAX gets no correctness surface from a 1F1B microbatch
schedule, but pipeline parallelism's *semantic* content — which stage owns
which layers, and how stage-local layer indices map back to the reference
numbering (paper Fig 5) — is fully modeled here:

* ``stage_division`` computes each stage's [start, end) global layer range,
  distributing any remainder one-per-stage from the front (Megatron-style
  uneven PP) so every layer runs exactly once for ANY (L, pp); with
  ``pp_wrong_stage_division`` injected, boundaries are computed with a
  rounded layers-per-stage (the classic ``ceil(L/pp)`` bug): one layer is
  executed twice at a stage boundary and another never runs — silent, loss
  still decreases, the model is simply wrong (paper bug 10).
* ``stage_layer_table`` precomputes, once, the (executed layer, canonical
  name index) pairs in execution order — the STAGE-LOCAL → global renaming
  (``canonical_layer_index``) that both the one-shot runner and the
  supervisor's once-compiled train step bake into their traced loss, so the
  mapping is preserved bit-for-bit across supervised steps.
* ``make_pp_runner`` executes the model stage by stage with stage-local
  numbering and canonical tap names aligned with the single-device
  reference; ``make_pp_train_step`` is the once-jitted stateful FULL train
  step (the supervisor's ``CandidateStep`` contract for ``--recipe pp``).
"""
from __future__ import annotations

import math

import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core.collector import Trace, make_trace_step, trace_fn_step
from repro.core.tap import ensure_ctx
from repro.models.model import Model, block_apply


def stage_division(n_layers: int, pp_size: int,
                   bugs=frozenset()) -> list[tuple[int, int]]:
    if "pp_wrong_stage_division" in bugs:
        # W-CP: ceil-based boundaries overlap by one layer per boundary and
        # drop the tail — stage i executes [i*cpl_bad, ...) with
        # cpl_bad = ceil(L/pp) clipped at L, so a layer repeats and the last
        # layer(s) never run.
        cpl = math.ceil(n_layers / pp_size) if pp_size > 1 else n_layers
        out = []
        for r in range(pp_size):
            start = min(r * cpl - (1 if r else 0), n_layers)
            end = min(start + cpl, n_layers)
            out.append((start, end))
        return out
    # exact partition: base layers per stage, remainder distributed
    # one-per-stage from the front (Megatron uneven pipeline division) —
    # floor alone would silently drop the last L % pp layers
    base, rem = divmod(n_layers, pp_size)
    out, start = [], 0
    for r in range(pp_size):
        end = start + base + (1 if r < rem else 0)
        out.append((start, end))
        start = end
    return out


def stage_layer_table(n_layers: int, pp_size: int,
                      bugs=frozenset()) -> list[tuple[int, int]]:
    """Static ``(executed_layer, canonical_index)`` pairs in execution order.

    The canonical index is reconstructed from (pp_rank, local index) under
    the CORRECT division — exactly the renaming a per-rank trace would apply
    (paper Fig 5; for divisible layer counts it coincides with
    ``core.canonical.canonical_layer_index``) — so when the injected bug
    shifts the executed ranges the names stay put and the trace misaligns
    with the reference.  Buggy
    overlapping stages can claim an already-used canonical index on uneven
    divisions; those spill to fresh indices >= L (absent from the reference,
    reported as extra candidate tensors) instead of colliding in one trace.
    """
    stages = stage_division(n_layers, pp_size, bugs)
    correct = stage_division(n_layers, pp_size)
    table, used, overflow = [], set(), n_layers
    for pp_rank, (start, end) in enumerate(stages):
        for local_idx in range(end - start):
            # the correct stage's offset + local index; for divisible L this
            # equals canonical_layer_index(local_idx, pp_rank, pp_size, 0, 1)
            # (asserted by the property tests against core.canonical)
            canon = correct[pp_rank][0] + local_idx
            if canon in used:
                canon, overflow = overflow, overflow + 1
            used.add(canon)
            table.append((start + local_idx, canon))
    return table


def _pp_loss_call(model: Model, pp_size: int, bugs=frozenset()):
    """``loss_call(params, batch, ctx)`` for the stage-partitioned candidate
    with canonical (global) tap names baked in — shared by the one-shot
    runner and the once-compiled supervised step."""
    cfg = model.cfg
    table = stage_layer_table(cfg.n_layers, pp_size, bugs)

    def loss_call(p, batch, ctx):
        ctx = ensure_ctx(ctx)
        h = model.embed(p, batch, ctx)
        from repro.models.layers import rmsnorm
        aux = jnp.zeros((), jnp.float32)
        for executed, canon in table:
            with ctx.scope(f"layers.{canon}"):
                h, a, _ = block_apply(p["layers"][executed], cfg,
                                      "attn_mlp", h, ctx)
            aux = aux + a
        h = rmsnorm(p["final_norm"], h)
        h = ctx.tap("final_norm_out", h)
        e = (p["embedding"]["word_embeddings"] if cfg.tie_embeddings
             else p["lm_head"])
        from repro.models.layers import cross_entropy, _logits
        return cross_entropy(_logits(h, e), batch["labels"]) + aux

    return loss_call


def make_pp_runner(model: Model, params, pp_size: int, opt=None,
                   opt_state=None, bugs=frozenset()):
    """Runner(batch, rewrites) -> Trace for the stage-partitioned candidate.

    Tap names use canonical (global) layer indices reconstructed from
    (pp_rank, local index) — identical to the reference's names when the
    division is correct."""
    loss_call = _pp_loss_call(model, pp_size, bugs)

    def run(batch, rewrites=None) -> Trace:
        tr, _, _ = trace_fn_step(loss_call, params, batch, opt=opt,
                                 opt_state=opt_state, rewrites=rewrites)
        return tr

    return run


def make_pp_train_step(model: Model, ref_params, opt, batch, pp_size: int,
                       bugs=frozenset()):
    """Once-compiled stateful PP candidate train step (supervisor contract).

    Returns ``(step, params0, opt_state0)`` with ``step(params, opt_state,
    batch) -> (Trace, new_params, new_opt_state)`` — one jitted callable,
    the stage-local → canonical tap renaming traced in, reused verbatim
    every supervised step and bisection replay."""
    import jax
    loss_call = _pp_loss_call(model, pp_size, bugs)
    step = make_trace_step(loss_call, opt, ref_params, batch)
    params0 = jax.tree.map(jnp.asarray, ref_params)
    return step, params0, opt.init(params0)
