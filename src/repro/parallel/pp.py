"""Pipeline-parallel staged execution + the stage-division silent bug.

Single-controller JAX gets no correctness surface from a 1F1B microbatch
schedule, but pipeline parallelism's *semantic* content — which stage owns
which layers, and how stage-local layer indices map back to the reference
numbering (paper Fig 5) — is fully modeled here:

* ``stage_division`` computes each stage's [start, end) global layer range;
  with ``pp_wrong_stage_division`` injected, boundaries are computed with a
  rounded layers-per-stage (the classic ``ceil(L/pp)`` bug): one layer is
  executed twice at a stage boundary and another never runs — silent, loss
  still decreases, the model is simply wrong (paper bug 10).
* ``make_pp_runner`` executes the model stage by stage with STAGE-LOCAL
  layer numbering, then canonicalizes tap names via
  ``canonical_layer_index`` so the trace aligns with the single-device
  reference — exercising the paper's canonical-module-name machinery on a
  real trace rather than only in unit tests.
"""
from __future__ import annotations

import math

import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core.canonical import canonical_layer_index
from repro.core.collector import Trace, trace_fn_step
from repro.core.tap import ensure_ctx
from repro.models.model import Model, block_apply


def stage_division(n_layers: int, pp_size: int,
                   bugs=frozenset()) -> list[tuple[int, int]]:
    if "pp_wrong_stage_division" in bugs:
        # W-CP: ceil-based boundaries overlap by one layer per boundary and
        # drop the tail — stage i executes [i*cpl_bad, ...) with
        # cpl_bad = ceil(L/pp) clipped at L, so a layer repeats and the last
        # layer(s) never run.
        cpl = math.ceil(n_layers / pp_size) if pp_size > 1 else n_layers
        out = []
        for r in range(pp_size):
            start = min(r * cpl - (1 if r else 0), n_layers)
            end = min(start + cpl, n_layers)
            out.append((start, end))
        return out
    cpl = n_layers // pp_size
    return [(r * cpl, (r + 1) * cpl) for r in range(pp_size)]


def make_pp_runner(model: Model, params, pp_size: int, opt=None,
                   opt_state=None, bugs=frozenset()):
    """Runner(batch, rewrites) -> Trace for the stage-partitioned candidate.

    Tap names use canonical (global) layer indices reconstructed from
    (pp_rank, local index) — identical to the reference's names when the
    division is correct."""
    cfg = model.cfg
    L = cfg.n_layers
    stages = stage_division(L, pp_size, bugs)

    def loss_call(p, batch, ctx):
        ctx = ensure_ctx(ctx)
        h = model.embed(p, batch, ctx)
        from repro.models.layers import rmsnorm
        aux = jnp.zeros((), jnp.float32)
        for pp_rank, (start, end) in enumerate(stages):
            for local_idx in range(end - start):
                executed = start + local_idx           # the layer that RUNS
                canon = canonical_layer_index(
                    local_idx, pp_rank, pp_size, 0, 1,
                    n_layers=L) if L % pp_size == 0 else executed
                with ctx.scope(f"layers.{canon}"):
                    h, a, _ = block_apply(p["layers"][executed], cfg,
                                          "attn_mlp", h, ctx)
                aux = aux + a
        h = rmsnorm(p["final_norm"], h)
        h = ctx.tap("final_norm_out", h)
        e = (p["embedding"]["word_embeddings"] if cfg.tie_embeddings
             else p["lm_head"])
        from repro.models.layers import cross_entropy, _logits
        return cross_entropy(_logits(h, e), batch["labels"]) + aux

    def run(batch, rewrites=None) -> Trace:
        tr, _, _ = trace_fn_step(loss_call, params, batch, opt=opt,
                                 opt_state=opt_state, rewrites=rewrites)
        return tr

    return run
