"""Pallas fused relative-Frobenius-error reduction — the checker's hot loop.

TTrace's equivalence checker computes ||A - B||_F / ||A||_F over every traced
tensor; the paper implements this in multithreaded C++ to dodge the GIL.  The
TPU-idiomatic equivalent is a single fused pass: one kernel walks both
tensors block-by-block accumulating sum((a-b)^2) and sum(a^2) in SMEM-scale
scratch, so neither the difference tensor nor a second read of A is ever
materialized in HBM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _relerr_kernel(a_ref, b_ref, out_ref, acc_ref, *, nb: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[...].astype(jnp.float32)
    b = b_ref[...].astype(jnp.float32)
    d = a - b
    acc_ref[0] += jnp.sum(d * d)
    acc_ref[1] += jnp.sum(a * a)

    @pl.when(i == nb - 1)
    def _emit():
        out_ref[...] = acc_ref[...]


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def sq_norms(a, b, block: int = 65536, interpret: bool = True):
    """Returns (||a-b||^2, ||a||^2) in one fused pass."""
    af = a.reshape(-1)
    bf = b.reshape(-1)
    n = af.shape[0]
    pad = -n % block if n > block else block - n
    if pad:
        af = jnp.pad(af, (0, pad))
        bf = jnp.pad(bf, (0, pad))
    nb = af.shape[0] // block
    kernel = functools.partial(_relerr_kernel, nb=nb)
    out = pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[pl.BlockSpec((block,), lambda i: (i,)),
                  pl.BlockSpec((block,), lambda i: (i,))],
        out_specs=pl.BlockSpec((2,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((2,), jnp.float32),
        scratch_shapes=[pltpu.VMEM((2,), jnp.float32)],
        interpret=interpret,
    )(af, bf)
    return out[0], out[1]


def rel_err_fused(a, b, interpret: bool = True) -> float:
    d2, a2 = sq_norms(jnp.asarray(a), jnp.asarray(b), interpret=interpret)
    d2, a2 = float(d2), float(a2)
    return (d2 ** 0.5) / (a2 ** 0.5) if a2 > 0 else d2 ** 0.5
