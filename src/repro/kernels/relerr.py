"""Pallas fused relative-Frobenius-error reductions — the checker's hot loop.

TTrace's equivalence checker computes ||A - B||_F / ||A||_F over every traced
tensor; the paper implements this in multithreaded C++ to dodge the GIL.  The
TPU-idiomatic equivalent is a *packed segmented* reduction: all N tensor
pairs of a trace section are concatenated (block-aligned) into two flat
buffers, and ONE grid launch walks both buffers block-by-block, accumulating
``(||a-b||^2, ||a||^2)`` into the row of an (N, 2) output selected by the
block's scalar-prefetched segment id.  Neither the difference tensor nor a
second read of A is ever materialized in HBM, and the host pulls back only
N x 2 floats.

Layout contract (produced by repro.core.relerr_engine.pack_sections):

* each pair's elements are flattened and placed at a ``block``-aligned
  offset; the tail of its last block is zero-filled,
* ``seg_ids[i]`` is the pair index owning block i (blocks never straddle
  pairs),
* ``counts[i]`` is the number of valid elements in block i (== block except
  for each pair's ragged last block); the kernel masks the zero-fill, so
  NaN/Inf garbage in padding can never leak into a verdict.

``sq_norms`` (single pair) is a thin wrapper over the packed kernel with
N == 1.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# Packed blocks are (BLOCK // LANES, LANES) f32 tiles; 1024 = 8 x 128, the
# native TPU vreg tile, and small enough that per-pair alignment padding is
# negligible for trace-scale tensors.
LANES = 128
DEFAULT_BLOCK = 1024


def default_interpret() -> bool:
    """Interpret mode is for backends with no Mosaic lowering (CPU here);
    on TPU the same kernels compile."""
    return jax.default_backend() != "tpu"


# ---------------------------------------------------------------------------
# packed segmented kernel
# ---------------------------------------------------------------------------

def _packed_relerr_kernel(seg_ref, cnt_ref, a_ref, b_ref, out_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    a = a_ref[...].astype(jnp.float32)
    b = b_ref[...].astype(jnp.float32)
    rows, lanes = a.shape
    lin = (jax.lax.broadcasted_iota(jnp.int32, (rows, lanes), 0) * lanes
           + jax.lax.broadcasted_iota(jnp.int32, (rows, lanes), 1))
    # select, not multiply-by-0/1: 0 * NaN is NaN, and the padding contract
    # must hold even over garbage tails (e.g. reused buffers)
    valid = lin < cnt_ref[i]
    d = jnp.where(valid, a - b, 0.0)
    a = jnp.where(valid, a, 0.0)
    seg = seg_ref[i]
    upd = jnp.stack([jnp.sum(d * d), jnp.sum(a * a)]).reshape(1, 2)
    cur = pl.load(out_ref, (pl.ds(seg, 1), slice(None)))
    pl.store(out_ref, (pl.ds(seg, 1), slice(None)), cur + upd)


@functools.partial(jax.jit,
                   static_argnames=("n_segments", "block", "interpret"))
def packed_sq_norms(a_flat, b_flat, seg_ids, counts, n_segments: int,
                    block: int = DEFAULT_BLOCK,
                    interpret: bool | None = None):
    """One grid launch over the packed section -> (n_segments, 2) f32 of
    ``(||a-b||^2, ||a||^2)`` per pair.

    ``a_flat``/``b_flat``: packed flat buffers, length divisible by
    ``block``; ``seg_ids``/``counts``: int32 per-block metadata (see module
    docstring).  ``interpret=None`` auto-selects from the backend.
    """
    if interpret is None:
        interpret = default_interpret()
    assert block % LANES == 0, f"block {block} must be a multiple of {LANES}"
    rows = block // LANES
    nb = a_flat.shape[0] // block
    a2 = a_flat.reshape(nb * rows, LANES)
    b2 = b_flat.reshape(nb * rows, LANES)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(nb,),
        in_specs=[pl.BlockSpec((rows, LANES), lambda i, *_: (i, 0)),
                  pl.BlockSpec((rows, LANES), lambda i, *_: (i, 0))],
        out_specs=pl.BlockSpec((n_segments, 2), lambda i, *_: (0, 0)),
    )
    return pl.pallas_call(
        _packed_relerr_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_segments, 2), jnp.float32),
        interpret=interpret,
    )(seg_ids, counts, a2, b2)


def packed_sq_norms_xla(a_flat, b_flat, seg_ids, n_segments: int,
                        block: int = DEFAULT_BLOCK):
    """Pure-XLA executor of the same packed layout (the kernel's oracle and
    the compiled fallback on backends without Mosaic).  Padding is
    zero-filled by the packing contract, so no mask is needed: zeros
    contribute nothing to either sum."""
    a = a_flat.astype(jnp.float32)
    b = b_flat.astype(jnp.float32)
    nb = a.shape[0] // block
    d = a - b
    dd = jnp.sum((d * d).reshape(nb, block), axis=1)
    aa = jnp.sum((a * a).reshape(nb, block), axis=1)
    return jax.ops.segment_sum(jnp.stack([dd, aa], axis=1), seg_ids,
                               num_segments=n_segments)


# ---------------------------------------------------------------------------
# single-pair wrappers (legacy surface)
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def sq_norms(a, b, block: int = 65536,
             interpret: bool | None = None):
    """Returns (||a-b||^2, ||a||^2) for ONE pair — thin wrapper over the
    packed segmented kernel with a single segment.

    The default block is much larger than the packed layout's
    DEFAULT_BLOCK: with N == 1 there is no alignment waste, and fewer grid
    steps means less per-step overhead (especially in interpret mode)."""
    af = jnp.asarray(a).reshape(-1).astype(jnp.float32)
    bf = jnp.asarray(b).reshape(-1).astype(jnp.float32)
    n = af.shape[0]
    pad = -n % block if n else block
    if pad:
        af = jnp.pad(af, (0, pad))
        bf = jnp.pad(bf, (0, pad))
    nb = af.shape[0] // block
    seg_ids = jnp.zeros((nb,), jnp.int32)
    counts = jnp.clip(n - jnp.arange(nb, dtype=jnp.int32) * block, 0, block)
    out = packed_sq_norms(af, bf, seg_ids, counts, n_segments=1,
                          block=block, interpret=interpret)
    return out[0, 0], out[0, 1]


def rel_err_fused(a, b, interpret: bool | None = None) -> float:
    d2, a2 = sq_norms(a, b, interpret=interpret)
    d2, a2 = float(d2), float(a2)
    return (d2 ** 0.5) / (a2 ** 0.5) if a2 > 0 else d2 ** 0.5
