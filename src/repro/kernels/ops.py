"""Jitted public wrappers for the Pallas kernels.

``INTERPRET`` defaults to True because this container has no TPU; on real
hardware set ``repro.kernels.ops.INTERPRET = False`` (or the
REPRO_PALLAS_INTERPRET=0 env var) and the same kernels compile to Mosaic.
"""
from __future__ import annotations

import os

import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import fp8_matmul as _mm
from repro.kernels import relerr as _re
from repro.kernels import ssm_scan as _ssm

INTERPRET = os.environ.get("REPRO_PALLAS_INTERPRET", "1") != "0"


def flash_attention(q, k, v, mode="causal", window=0, bq=512, bk=512):
    return _fa.flash_attention(q, k, v, mode=mode, window=window, bq=bq,
                               bk=bk, interpret=INTERPRET)


def gla_scan(q, k, v, log_w, chunk=128, exclusive=False, u=None):
    """Kernel-backed equivalent of models.ssm.lin_attn_chunked (s0=0)."""
    y, s = _ssm.gla_scan(q, k, v, log_w, chunk=chunk, exclusive=exclusive,
                         interpret=INTERPRET)
    if u is not None:
        bonus = jnp.einsum("bshk,hk,bshk->bsh", q.astype(jnp.float32),
                           u.astype(jnp.float32), k.astype(jnp.float32))
        y = y + bonus[..., None] * v.astype(jnp.float32)
    return y.astype(v.dtype), s


def fp8_matmul(x, w, bm=256, bn=256, bk=256):
    return _mm.fp8_matmul(x, w, bm=bm, bn=bn, bk=bk, interpret=INTERPRET)


def rel_err(a, b) -> float:
    return _re.rel_err_fused(a, b, interpret=INTERPRET)
