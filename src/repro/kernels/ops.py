"""Jitted public wrappers for the Pallas kernels.

Interpret mode is auto-selected from the backend: compiled Mosaic on TPU,
interpreter elsewhere (this container has no TPU).  Override with the
REPRO_PALLAS_INTERPRET env var (0/1) or by setting
``repro.kernels.ops.INTERPRET`` to True/False directly; ``INTERPRET =
None`` means auto.  Auto-selection happens at call time, not import time —
importing this module must not initialize the JAX backend (scripts set
XLA_FLAGS after imports).
"""
from __future__ import annotations

import os

import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import fp8_matmul as _mm
from repro.kernels import relerr as _re
from repro.kernels import ssm_scan as _ssm

_env = os.environ.get("REPRO_PALLAS_INTERPRET")
INTERPRET = (_env != "0") if _env is not None else None


def interpret_mode() -> bool:
    return _re.default_interpret() if INTERPRET is None else INTERPRET


def flash_attention(q, k, v, mode="causal", window=0, bq=512, bk=512):
    return _fa.flash_attention(q, k, v, mode=mode, window=window, bq=bq,
                               bk=bk, interpret=interpret_mode())


def gla_scan(q, k, v, log_w, chunk=128, exclusive=False, u=None):
    """Kernel-backed equivalent of models.ssm.lin_attn_chunked (s0=0)."""
    y, s = _ssm.gla_scan(q, k, v, log_w, chunk=chunk, exclusive=exclusive,
                         interpret=interpret_mode())
    if u is not None:
        bonus = jnp.einsum("bshk,hk,bshk->bsh", q.astype(jnp.float32),
                           u.astype(jnp.float32), k.astype(jnp.float32))
        y = y + bonus[..., None] * v.astype(jnp.float32)
    return y.astype(v.dtype), s


def fp8_matmul(x, w, bm=256, bn=256, bk=256):
    return _mm.fp8_matmul(x, w, bm=bm, bn=bn, bk=bk,
                          interpret=interpret_mode())


def fp8_matmul_tile128(x, sx, w, sw):
    """Per-128x128-tile-scaled fp8 matmul (compact tile scales ride along)."""
    return _mm.fp8_matmul_tile128(x, sx, w, sw, interpret=interpret_mode())


def rel_err(a, b) -> float:
    return _re.rel_err_fused(a, b, interpret=interpret_mode())


def packed_sq_norms(a_flat, b_flat, seg_ids, counts, n_segments,
                    block=_re.DEFAULT_BLOCK):
    """Packed segmented (||a-b||^2, ||a||^2) over N pairs in one launch."""
    return _re.packed_sq_norms(a_flat, b_flat, seg_ids, counts,
                               n_segments=n_segments, block=block,
                               interpret=interpret_mode())
