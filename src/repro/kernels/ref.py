"""Pure-jnp oracles for every Pallas kernel (the kernel test contracts)."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

# flash attention oracle: the naive reference in the model zoo
from repro.models.attention import attention_ref  # noqa: F401

# gla_scan oracle: the step-by-step recurrence
from repro.models.ssm import lin_attn_recurrent, lin_attn_chunked  # noqa: F401


def gla_scan_ref(q, k, v, log_w, exclusive=False):
    """Recurrent (sequential) oracle matching kernels.ssm_scan.gla_scan."""
    u = jnp.zeros((q.shape[2], q.shape[3]), jnp.float32) if exclusive else None
    y, s = lin_attn_recurrent(q, k, v, log_w, u=u)
    return y.astype(jnp.float32), s


def fp8_matmul_ref(x, w):
    return jnp.matmul(x.astype(jnp.float32), w.astype(jnp.float32))


def rel_err_ref(a, b) -> float:
    a64 = np.asarray(a, np.float64)
    b64 = np.asarray(b, np.float64)
    na = np.linalg.norm(a64)
    d = np.linalg.norm(a64 - b64)
    return float(d / na) if na > 0 else float(d)
