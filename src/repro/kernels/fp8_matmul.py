"""Pallas TPU tiled FP8 (e4m3) matmul with fp32 accumulation.

Grid (M/bm, N/bn, K/bk); the K axis is sequential with an (bm, bn) fp32 VMEM
accumulator.  Operands arrive pre-quantized (float8_e4m3fn) with scales
applied outside (repro.precision.fp8 owns the recipes); on MXU-native-fp8
TPUs the dot stays in fp8, elsewhere operands upcast in-register.  Block
shapes default to (256, 256, 256) — multiples of the (8,128)/(128,128)
MXU tiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _mm_kernel(x_ref, w_ref, o_ref, acc_ref, *, nk: int):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32)
    acc_ref[...] += jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(ki == nk - 1)
    def _emit():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def fp8_matmul(x, w, bm: int = 256, bn: int = 256, bk: int = 256,
               interpret: bool = True):
    """x: (M,K) float8_e4m3fn; w: (K,N) float8_e4m3fn -> (M,N) float32."""
    M, K = x.shape
    K2, N = w.shape
    assert K == K2
    bm, bn, bk = min(bm, M), min(bn, N), min(bk, K)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0, (M, N, K, bm, bn, bk)
    kernel = functools.partial(_mm_kernel, nk=K // bk)
    return pl.pallas_call(
        kernel,
        grid=(M // bm, N // bn, K // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda mi, ni, ki: (mi, ki)),
            pl.BlockSpec((bk, bn), lambda mi, ni, ki: (ki, ni)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda mi, ni, ki: (mi, ni)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, w)
