"""Pallas TPU tiled FP8 (e4m3) matmuls with fp32 accumulation.

Two variants share the same grid shape (M/bm, N/bn, K/bk) with a sequential
K axis and an (bm, bn) fp32 VMEM accumulator:

* ``fp8_matmul`` — operands arrive pre-quantized (float8_e4m3fn) with ONE
  scale per operand applied outside (repro.precision.fp8 owns the recipes);
* ``fp8_matmul_tile128`` — the DeepSeek-style per-128x128-tile recipe:
  compact per-tile scale arrays ride along and the block's
  ``sx[mi,ki] * sw[ki,ni]`` product is applied inside the K loop (per-tile
  scales vary along the contraction, so they CANNOT be folded outside).
  Blocks are fixed at the 128 tile size so each grid step covers exactly
  one scale entry per operand.

On MXU-native-fp8 TPUs the dot stays in fp8, elsewhere operands upcast
in-register.  Plain-variant block shapes default to (256, 256, 256) —
multiples of the (8,128)/(128,128) MXU tiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _mm_kernel(x_ref, w_ref, o_ref, acc_ref, *, nk: int):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32)
    acc_ref[...] += jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(ki == nk - 1)
    def _emit():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def fp8_matmul(x, w, bm: int = 256, bn: int = 256, bk: int = 256,
               interpret: bool = True):
    """x: (M,K) float8_e4m3fn; w: (K,N) float8_e4m3fn -> (M,N) float32."""
    M, K = x.shape
    K2, N = w.shape
    assert K == K2
    bm, bn, bk = min(bm, M), min(bn, N), min(bk, K)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0, (M, N, K, bm, bn, bk)
    kernel = functools.partial(_mm_kernel, nk=K // bk)
    return pl.pallas_call(
        kernel,
        grid=(M // bm, N // bn, K // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda mi, ni, ki: (mi, ki)),
            pl.BlockSpec((bk, bn), lambda mi, ni, ki: (ki, ni)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda mi, ni, ki: (mi, ni)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, w)


TILE = 128


def _mm_tile_kernel(x_ref, w_ref, sx_ref, sw_ref, o_ref, acc_ref, *, nk: int):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32)
    # one 128-block == one quantization tile: the per-tile dequant scale of
    # this K step is the scalar product sx[mi, ki] * sw[ki, ni]
    s = sx_ref[0, 0] * sw_ref[0, 0]
    acc_ref[...] += s * jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(ki == nk - 1)
    def _emit():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def fp8_matmul_tile128(x, sx, w, sw, interpret: bool = True):
    """Per-128x128-tile-scaled fp8 matmul (the DeepSeek-V3 recipe).

    x: (M,K) float8_e4m3fn with compact tile scales sx: (M/128, K/128) f32;
    w: (K,N) float8_e4m3fn with sw: (K/128, N/128) f32 -> (M,N) float32,
    mathematically ``(x_deq @ w_deq)`` with per-element dequantization but
    without ever materializing the dequantized operands in HBM.
    """
    M, K = x.shape
    K2, N = w.shape
    assert K == K2
    assert M % TILE == 0 and N % TILE == 0 and K % TILE == 0, (M, N, K)
    assert sx.shape == (M // TILE, K // TILE), (sx.shape, x.shape)
    assert sw.shape == (K // TILE, N // TILE), (sw.shape, w.shape)
    kernel = functools.partial(_mm_tile_kernel, nk=K // TILE)
    return pl.pallas_call(
        kernel,
        grid=(M // TILE, N // TILE, K // TILE),
        in_specs=[
            pl.BlockSpec((TILE, TILE), lambda mi, ni, ki: (mi, ki)),
            pl.BlockSpec((TILE, TILE), lambda mi, ni, ki: (ki, ni)),
            pl.BlockSpec((1, 1), lambda mi, ni, ki: (mi, ki)),
            pl.BlockSpec((1, 1), lambda mi, ni, ki: (ki, ni)),
        ],
        out_specs=pl.BlockSpec((TILE, TILE), lambda mi, ni, ki: (mi, ni)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        scratch_shapes=[pltpu.VMEM((TILE, TILE), jnp.float32)],
        interpret=interpret,
    )(x, w, sx, sw)
