"""Pallas TPU chunked gated-linear-attention scan (Mamba2 SSD / RWKV-6 core).

State-space recurrence  S_t = diag(w_t) S_{t-1} + k_t v_t^T,  y_t = q_t S_t
in chunked form: the grid walks (batch*heads, n_chunks) with the chunk axis
sequential; the per-head state (dk, dv) lives in fp32 VMEM scratch and is
carried across chunks.  Within a chunk everything is dense matmuls (MXU),
using the clamped "safe gate" factorization — identical math to
``repro.models.ssm.lin_attn_chunked``, which doubles as this kernel's oracle
(with the recurrent scan as the independent gold reference).

``exclusive=True`` reads S_{t-1} instead of S_t (the RWKV-6 convention); the
current-token bonus u is a cheap elementwise term added by the wrapper.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

CLAMP = 20.0


def _gla_kernel(q_ref, k_ref, v_ref, lw_ref, y_ref, sfin_ref, state_ref, *,
                chunk: int, nc: int, exclusive: bool, scalar_decay: bool):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    q = q_ref[...].astype(jnp.float32)          # (C, dk)
    k = k_ref[...].astype(jnp.float32)
    v = v_ref[...].astype(jnp.float32)          # (C, dv)
    lw = lw_ref[...].astype(jnp.float32)        # (C, dk)

    L = jnp.cumsum(lw, axis=0)
    Lq = L - lw if exclusive else L
    q_t = q * jnp.exp(Lq)
    if scalar_decay:
        # exact relative decay (SSD segsum): scalar per head, no clamping
        D = jnp.exp(jnp.minimum(Lq[:, 0][:, None] - L[:, 0][None, :], 0.0))
        A = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * D
    else:
        k_t = k * jnp.exp(-jnp.maximum(L, -CLAMP))
        A = jax.lax.dot_general(q_t, k_t, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
    t_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    s_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    causal = (s_idx < t_idx) if exclusive else (s_idx <= t_idx)
    A = jnp.where(causal, A, 0.0)

    s = state_ref[...]                          # (dk, dv)
    y = (jax.lax.dot_general(A, v, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
         + jax.lax.dot_general(q_t, s, (((1,), (0,)), ((), ())),
                               preferred_element_type=jnp.float32))
    y_ref[...] = y.astype(y_ref.dtype)

    Lc = L[-1:, :]                              # (1, dk)
    k_dec = k * jnp.exp(Lc - L)
    state_ref[...] = (jnp.exp(Lc[0])[:, None] * s
                      + jax.lax.dot_general(
                          k_dec, v, (((0,), (0,)), ((), ())),
                          preferred_element_type=jnp.float32))

    @pl.when(ci == nc - 1)
    def _emit_state():
        sfin_ref[...] = state_ref[...]


@functools.partial(jax.jit, static_argnames=("chunk", "exclusive",
                                             "interpret"))
def gla_scan(q, k, v, log_w, chunk: int = 128, exclusive: bool = False,
             interpret: bool = True):
    """q,k,log_w: (B,S,H,dk); v: (B,S,H,dv).
    Returns y (B,S,H,dv) fp32-accumulated, s_final (B,H,dk,dv) fp32."""
    B, S, H, dk = q.shape
    dv = v.shape[-1]
    dw = log_w.shape[-1]
    scalar_decay = dw == 1
    chunk = min(chunk, S)
    assert S % chunk == 0
    nc = S // chunk
    BH = B * H

    def fold(x):
        return x.transpose(0, 2, 1, 3).reshape(BH, S, x.shape[-1])

    qf, kf, vf, lwf = fold(q), fold(k), fold(v), fold(log_w)

    def seq_map(bh, ci):
        return (bh, ci, 0)

    def state_map(bh, ci):
        return (bh, 0, 0)

    kernel = functools.partial(_gla_kernel, chunk=chunk, nc=nc,
                               exclusive=exclusive,
                               scalar_decay=scalar_decay)
    y, sfin = pl.pallas_call(
        kernel,
        grid=(BH, nc),
        in_specs=[
            pl.BlockSpec((None, chunk, dk), seq_map),
            pl.BlockSpec((None, chunk, dk), seq_map),
            pl.BlockSpec((None, chunk, dv), seq_map),
            pl.BlockSpec((None, chunk, dw), seq_map),
        ],
        out_specs=[
            pl.BlockSpec((None, chunk, dv), seq_map),
            pl.BlockSpec((None, dk, dv), state_map),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, S, dv), jnp.float32),
            jax.ShapeDtypeStruct((BH, dk, dv), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((dk, dv), jnp.float32)],
        interpret=interpret,
    )(qf, kf, vf, lwf)
    y = y.reshape(B, H, S, dv).transpose(0, 2, 1, 3)
    return y, sfin.reshape(B, H, dk, dv)
