"""Pallas TPU flash attention (GQA, causal / sliding-window / bidirectional).

Online-softmax attention with explicit BlockSpec VMEM tiling:

  grid = (batch * q_heads, S/bq, S/bk)   — kv dim is the sequential axis
  q block   (bq, D) in VMEM
  k/v block (bk, D) in VMEM, indexed through h // G so GQA never
            materializes repeated KV heads
  scratch   m, l (bq,) and acc (bq, D) fp32 in VMEM, carried across the
            kv grid dimension; the output block is written on the last step.

MXU alignment: default bq=bk=512 blocks with D in {64, 128} keep the matmul
dims multiples of (8,128) tiles.  ``interpret=True`` (CPU container) runs the
same kernel body under the Pallas interpreter for validation against
``ref.attention_ref``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  mode: str, window: int, bq: int, bk: int, nk: int,
                  scale: float):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    if mode == "bidirectional":
        needed = ki >= 0
    elif mode == "swa":
        needed = (ki * bk <= qi * bq + bq - 1) & \
                 (ki * bk + bk - 1 > qi * bq - window)
    else:  # causal
        needed = ki * bk <= qi * bq + bq - 1

    @pl.when(needed)
    def _compute():
        q = q_ref[...].astype(jnp.float32)
        k = k_ref[...].astype(jnp.float32)
        v = v_ref[...].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if mode == "causal":
            mask = k_pos <= q_pos
        elif mode == "swa":
            mask = (k_pos <= q_pos) & (k_pos > q_pos - window)
        else:
            mask = None
        if mask is not None:
            s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
        acc_ref[...] = (acc_ref[...] * alpha[:, None]
                        + jax.lax.dot_general(
                            p, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32))
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[...] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("mode", "window", "bq", "bk",
                                             "interpret"))
def flash_attention(q, k, v, mode: str = "causal", window: int = 0,
                    bq: int = 512, bk: int = 512, interpret: bool = True):
    """q: (B,S,H,D); k/v: (B,S,Hkv,D).  Returns (B,S,H,D)."""
    B, S, H, D = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    bq = min(bq, S)
    bk = min(bk, S)
    assert S % bq == 0 and S % bk == 0
    nq, nk = S // bq, S // bk
    scale = 1.0 / (D ** 0.5)

    qt = q.transpose(0, 2, 1, 3).reshape(B * H, S, D)
    kt = k.transpose(0, 2, 1, 3).reshape(B * Hkv, S, D)
    vt = v.transpose(0, 2, 1, 3).reshape(B * Hkv, S, D)

    def q_map(bh, qi, ki):
        return (bh, qi, 0)

    def kv_map(bh, qi, ki):
        b, h = bh // H, bh % H
        return (b * Hkv + h // G, ki, 0)

    kernel = functools.partial(_flash_kernel, mode=mode, window=window,
                               bq=bq, bk=bk, nk=nk, scale=scale)
    out = pl.pallas_call(
        kernel,
        grid=(B * H, nq, nk),
        in_specs=[
            pl.BlockSpec((None, bq, D), q_map),
            pl.BlockSpec((None, bk, D), kv_map),
            pl.BlockSpec((None, bk, D), kv_map),
        ],
        out_specs=pl.BlockSpec((None, bq, D), q_map),
        out_shape=jax.ShapeDtypeStruct((B * H, S, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),       # running max
            pltpu.VMEM((bq,), jnp.float32),       # running denom
            pltpu.VMEM((bq, D), jnp.float32),     # running accumulator
        ],
        interpret=interpret,
    )(qt, kt, vt)
    return out.reshape(B, H, S, D).transpose(0, 2, 1, 3)
