"""Silent-bug injection registry — the evaluation surface for TTrace.

Reproduces the paper's Table 1 taxonomy against our own distributed backend:
every entry is a *silent* modification (no crash, no NaN, loss still goes
down) of the manual-parallelism code in ``repro/parallel``.  Injection is by
id: the parallel layers consult ``bugs`` (a frozenset of ids) at trace time.

Types follow the paper: W-CP (wrong computation), W-CM (wrong communication),
M-CM (missing communication).
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class BugSpec:
    bug_id: str
    btype: str          # W-CP | W-CM | M-CM
    paper_analogue: str  # Table 1 row this mirrors
    description: str
    impact: str
    expected_module: str  # module (or prefix) TTrace should localize to
    requires: tuple = ()  # parallel features that must be on ("tp","cp",...)


BUGS: dict[str, BugSpec] = {b.bug_id: b for b in [
    BugSpec("tp_wrong_embedding_mask", "W-CP", "bug 1 (TP wrong embedding mask)",
            "vocab-parallel embedding uses an off-by-one ownership mask; "
            "boundary tokens are embedded by two ranks and double-counted "
            "after the all-reduce",
            "wrong forward + gradients", "embedding*", ("tp",)),
    BugSpec("ar_stale_recompute", "W-CP", "bug 2 (AR wrong input)",
            "activation recomputation re-runs the MLP on a stale "
            "(token-shifted) input during the backward pass",
            "wrong gradients only", "layers.*.mlp*", ()),
    BugSpec("cp_wrong_loss_scale", "W-CP", "bug 3 (CP wrong loss scaling)",
            "per-rank loss contribution divided by local token count instead "
            "of global under context parallelism",
            "wrong gradients", "loss", ("cp",)),
    BugSpec("dp_wrong_loss_scale", "W-CP", "bug 4 (DP wrong loss scaling)",
            "data-parallel gradient all-reduce uses sum instead of mean",
            "wrong gradients (scaled by dp)", "loss", ("dp",)),
    BugSpec("zero_untied_embedding", "W-CM", "bug 5 (ZeRO embed/LM-head untied)",
            "with tied embeddings + ZeRO-1, the embedding and LM-head shards "
            "are updated from different owner ranks and drift apart",
            "wrong parameter update", "embedding*", ("zero1",)),
    BugSpec("moe_router_not_synced", "M-CM", "bug 6 (SP router not synced)",
            "router weights initialized per-rank without broadcast inside "
            "the TP group; routing decisions diverge across ranks",
            "wrong forward + gradients", "layers.*.mlp", ("tp", "moe")),
    BugSpec("tp_wrong_allreduce_axis", "W-CM", "bug 7 (wrong FP8 comm group)",
            "row-parallel output all-reduce runs over the dp axis instead of "
            "the tp axis",
            "wrong forward + gradients", "layers.*.self_attention", ("tp", "dp")),
    BugSpec("fp8_stale_scale", "W-CP", "bug 8 (AR wrong tensor by FP8 cast)",
            "fp8 matmul quantizes with a stale amax scale (previous tensor)",
            "wrong loss", "layers.*.mlp", ("fp8",)),
    BugSpec("zero_skipped_update", "W-CM", "bug 9 (ZeRO param update failure)",
            "ZeRO-1 all-gather after the step returns the pre-update shard "
            "for the last rank's partition; those params never train",
            "no parameter update (partial)", "optimizer", ("zero1",)),
    BugSpec("pp_wrong_stage_division", "W-CP", "bug 10 (PP wrong stage division)",
            "pipeline stage boundaries computed with a rounded layers-per-"
            "stage; one layer is executed twice, another skipped",
            "wrong model gets trained", "layers.*", ("pp",)),
    BugSpec("pp_microbatch_order", "W-CP",
            "Megatron microbatch-schedule bug class (Yu et al.)",
            "the 1F1B backward recompute reads the NEXT microbatch's "
            "stashed boundary input, so gradients are accumulated against "
            "the wrong microbatch's activations; the forward pass — and "
            "therefore the loss curve — is byte-identical to the correct "
            "schedule",
            "wrong gradients only", "layers.*", ("pp", "1f1b")),
    BugSpec("pp_stale_boundary", "W-CM",
            "boundary-communication bug class (Yu et al.)",
            "stage i+1 consumes the previous microbatch's boundary "
            "activation (stale recv buffer reuse); microbatch 0 is correct "
            "and every consumed tensor is a real activation, so the loss "
            "stays plausible and keeps decreasing",
            "wrong forward + gradients", "layers.*", ("pp", "1f1b")),
    BugSpec("sp_stale_wgrad", "W-CP", "bug 11 (wrong grads w/ overlap)",
            "row-parallel linear_proj weight gradient computed from a stale "
            "(half-zeroed) activation buffer, as if the overlapped backward "
            "all-gather never completed; forward and dgrad are correct",
            "wrong gradients only", "layers.*.self_attention*", ("tp", "sp")),
    BugSpec("tp_missing_grad_allreduce", "M-CM", "bug 11 class (missing grad AR)",
            "gradient of the (tp-replicated) input_norm weight is not "
            "all-reduced over the tp group under sequence parallelism",
            "wrong gradients", "layers.*.input_norm", ("tp", "sp")),
    BugSpec("sp_layernorm_not_synced", "M-CM", "bug 12 (SP layernorm not synced)",
            "with sequence parallelism, post_attn_norm weight grads come "
            "from local sequence shards and are never reduced over the sp "
            "group",
            "wrong gradients", "layers.*.post_attn_norm", ("tp", "sp")),
    BugSpec("cp_wrong_attention_grad", "W-CP", "bug 13 (CP wrong attn grads)",
            "context-parallel attention backward uses the first zigzag "
            "stripe's positions for both stripes (forward is correct)",
            "wrong gradients only", "layers.*.self_attention*", ("cp",)),
    BugSpec("tp_cp_wrong_norm_grad", "W-CP", "bug 14 (TP+CP wrong LN grads)",
            "input_norm weight gradient is reduced over the sp group but "
            "its context-parallel reduction is skipped when TP+CP combine",
            "wrong gradients", "layers.*.input_norm", ("tp", "cp")),
    BugSpec("tp_missing_row_psum", "M-CM", "classic missing all-reduce",
            "row-parallel MLP down-projection output is never all-reduced; "
            "each rank continues with a partial sum",
            "wrong forward + gradients", "layers.*.mlp", ("tp",)),
]}


def bug(bug_id: str) -> BugSpec:
    return BUGS[bug_id]


def available_for(features: set[str]) -> list[BugSpec]:
    return [b for b in BUGS.values() if set(b.requires) <= features]
