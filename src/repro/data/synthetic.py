"""Deterministic synthetic data pipeline.

All samples are generated statelessly from (seed, step, shard) via
``jax.random.fold_in`` so every data-parallel rank, the single-device
reference, and any restart reproduce bit-identical batches — the data-side
half of TTrace's "consistent distributed tensor generator" guarantee
(paper §4.2): the reference and the candidate must consume identical inputs.

Token streams follow a Zipf-like marginal (realistic logit/loss magnitudes);
audio/vision frontends are stubbed with Gaussian frame/patch features of the
configured dims.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, InputShape


def _tokens(key, batch, seq, vocab):
    """Zipf-ish token stream: rank ~ exp(u * log(V))."""
    u = jax.random.uniform(key, (batch, seq), jnp.float32, 1e-6, 1.0)
    alpha = 1.1
    ranks = jnp.power(u, -1.0 / (alpha - 1.0))          # pareto
    toks = jnp.clip(ranks.astype(jnp.int32) - 1, 0, vocab - 1)
    perm = jax.random.permutation(jax.random.fold_in(key, 1), vocab)
    return perm[toks]


def make_batch(cfg: ArchConfig, batch: int, seq: int, *, seed: int = 0,
               step: int = 0) -> dict:
    """One global batch for ``train_step``/``prefill_step``."""
    key = jax.random.fold_in(jax.random.PRNGKey(seed), step)
    if cfg.arch_type == "audio":
        kf, km, kt = jax.random.split(key, 3)
        feats = jax.random.normal(kf, (batch, seq, cfg.audio_dim), jnp.float32)
        mask = jax.random.bernoulli(km, 0.08, (batch, seq))
        targets = jax.random.randint(kt, (batch, seq), 0, cfg.vocab)
        return {"features": feats, "mask": mask, "labels": targets}
    if cfg.arch_type == "vlm":
        n_img = min(cfg.n_image_tokens, max(seq - 16, 1))
        text_len = seq - n_img
        ki, kt = jax.random.split(key)
        img = jax.random.normal(ki, (batch, n_img, cfg.vision_dim),
                                jnp.float32)
        toks = _tokens(kt, batch, text_len + 1, cfg.vocab)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:],
                "image_embeds": img}
    toks = _tokens(key, batch, seq + 1, cfg.vocab)
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


def make_decode_inputs(cfg: ArchConfig, batch: int, *, seed: int = 0,
                       step: int = 0) -> dict:
    key = jax.random.fold_in(jax.random.PRNGKey(1000 + seed), step)
    toks = jax.random.randint(key, (batch, 1), 0, cfg.vocab)
    return {"tokens": toks}


class DataLoader:
    """Iterator facade over the stateless generator (launcher-facing)."""

    def __init__(self, cfg: ArchConfig, shape: InputShape, seed: int = 0):
        self.cfg, self.shape, self.seed = cfg, shape, seed
        self.step = 0

    def __iter__(self):
        return self

    def __next__(self):
        b = make_batch(self.cfg, self.shape.global_batch, self.shape.seq_len,
                       seed=self.seed, step=self.step)
        self.step += 1
        return b
