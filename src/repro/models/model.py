"""Model assembly: builds every assigned architecture from an ArchConfig.

A model is a sequence of *segments*; each segment is either a stack of
identical layers (optionally executed with ``jax.lax.scan`` over stacked
parameters — the big dry-run configs) or a single block (e.g. zamba2's
shared-parameter attention block, deepseek's leading dense-FFN layer).

Public API (pure functions of params):
    m = Model(cfg)
    params = m.init(rng)
    h             = m.forward(params, batch, ctx)
    loss, metrics = m.loss(params, batch, ctx)
    cache         = m.init_cache(batch_size, seq_len)
    logits, cache = m.decode_step(params, cache, tokens, pos)

VLM / audio frontends are stubs per the assignment: ``batch`` carries
precomputed patch embeddings / frame features; the trained projector and the
transformer backbone are real.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core.tap import ensure_ctx, TraceContext
from repro.models import attention as attn_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import (
    chunked_cross_entropy, cross_entropy, embed_init, gelu_mlp, gelu_mlp_init,
    linear, linear_init, rmsnorm, swiglu_mlp, swiglu_mlp_init, _logits,
)
from repro.sharding.rules import constrain

# benchmarks/roofline sets this to force scan-free primitives (XLA counts
# loop bodies once, so cost analysis needs unrolled HLO)
COST_MODE = False


@dataclass(frozen=True)
class Segment:
    name: str          # params key; also the tap scope
    kind: str          # attn_mlp | attn_moe | rwkv | mamba | shared_attn
    n: int             # number of layers in this segment
    scan: bool         # lax.scan over stacked params
    layer0: int        # global index of the first layer (canonical naming)
    shared: bool = False  # params live under the shared key, not per-segment


def build_plan(cfg: ArchConfig) -> list[Segment]:
    segs: list[Segment] = []
    L = cfg.n_layers
    sc = cfg.scan_layers
    if cfg.arch_type in ("dense", "vlm", "audio"):
        if L > 0:
            segs.append(Segment("layers", "attn_mlp", L, sc and L > 1, 0))
    elif cfg.arch_type == "moe":
        nd = min(cfg.moe.n_dense_layers, L)
        if nd:
            segs.append(Segment("dense_layers", "attn_dense_mlp", nd,
                                False, 0))
        if L - nd > 0:
            segs.append(Segment("layers", "attn_moe", L - nd,
                                sc and L - nd > 1, nd))
    elif cfg.arch_type == "ssm":
        segs.append(Segment("layers", "rwkv", L, sc and L > 1, 0))
    elif cfg.arch_type == "hybrid":
        k = cfg.hybrid.attn_every
        i = 0
        g = 0
        while i < L:
            n = min(k, L - i)
            segs.append(Segment(f"mamba{g}", "mamba", n, sc and n > 1, i))
            i += n
            if i <= L - 0 and n == k and cfg.hybrid.shared_attn:
                segs.append(Segment(f"shared_attn_{g}", "shared_attn", 1,
                                    False, i, shared=True))
            g += 1
    else:
        raise ValueError(cfg.arch_type)
    return segs


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------

def _out_scale(cfg):  # megatron-style scaled residual-output init
    import math
    return 0.02 / math.sqrt(2.0 * max(cfg.n_layers, 1))


def block_init(rng, cfg: ArchConfig, kind: str, dtype):
    osc = float(_out_scale(cfg))
    k1, k2, k3, k4 = jax.random.split(rng, 4)
    if kind in ("attn_mlp", "attn_dense_mlp", "attn_moe", "shared_attn"):
        p = {"input_norm": jnp.ones((cfg.d_model,), dtype),
             "post_attn_norm": jnp.ones((cfg.d_model,), dtype)}
        if cfg.attn == "mla":
            p["self_attention"] = attn_mod.mla_init(k1, cfg, dtype, osc)
        else:
            p["self_attention"] = attn_mod.gqa_init(k1, cfg, dtype, osc)
        if kind == "attn_moe":
            p["mlp"] = moe_mod.moe_init(k2, cfg, dtype, osc)
        elif kind == "attn_dense_mlp":
            dff = cfg.moe.d_ff_dense or cfg.d_ff
            p["mlp"] = swiglu_mlp_init(k2, cfg.d_model, dff, dtype, osc)
        elif cfg.arch_type == "audio":
            p["mlp"] = gelu_mlp_init(k2, cfg.d_model, cfg.d_ff, dtype, osc)
        else:
            p["mlp"] = swiglu_mlp_init(k2, cfg.d_model, cfg.d_ff, dtype, osc)
        return p
    if kind == "rwkv":
        p = ssm_mod.rwkv6_init(k1, cfg, dtype, osc)
        p["input_norm"] = jnp.ones((cfg.d_model,), dtype)
        p["post_tm_norm"] = jnp.ones((cfg.d_model,), dtype)
        return p
    if kind == "mamba":
        return {"input_norm": jnp.ones((cfg.d_model,), dtype),
                "mixer": ssm_mod.mamba2_init(k1, cfg, dtype, osc)}
    raise ValueError(kind)


def block_apply(p, cfg: ArchConfig, kind: str, x, ctx, cache=None, pos=None,
                decode=False, use_kernel=False, precision=None):
    """Returns (x, aux_loss, new_cache).  ``precision`` (an optional
    ``repro.precision.fp8.Precision``) routes the MLP matmuls through the
    FP8 recipe; everything else stays in the compute dtype."""
    ctx = ensure_ctx(ctx)
    aux = jnp.zeros((), jnp.float32)
    if kind in ("attn_mlp", "attn_dense_mlp", "attn_moe", "shared_attn"):
        h = rmsnorm(p["input_norm"], x)
        with ctx.scope("self_attention"):
            if decode:
                if cfg.attn == "mla":
                    a, cache = attn_mod.mla_decode(p["self_attention"], cfg, h,
                                                   cache, pos)
                else:
                    a, cache = attn_mod.gqa_decode(p["self_attention"], cfg, h,
                                                   cache, pos)
            else:
                if cfg.attn == "mla":
                    a = attn_mod.mla_forward(p["self_attention"], cfg, h,
                                             ctx=ctx)
                else:
                    a = attn_mod.gqa_forward(p["self_attention"], cfg, h,
                                             ctx=ctx, use_kernel=use_kernel)
        x = x + a
        h = rmsnorm(p["post_attn_norm"], x)
        with ctx.scope("mlp"):
            if kind == "attn_moe":
                mo, aux = moe_mod.moe_forward(p["mlp"], cfg, h, ctx=ctx)
            elif cfg.arch_type == "audio":
                mo = gelu_mlp(p["mlp"], h, ctx=ctx, precision=precision)
            else:
                mo = swiglu_mlp(p["mlp"], h, ctx=ctx, precision=precision)
        x = x + mo
        return x, aux, cache
    if kind == "rwkv":
        st = cache or {"time_mix": None, "channel_mix": None}
        h = rmsnorm(p["input_norm"], x)
        with ctx.scope("time_mix"):
            tm, new_tm = ssm_mod.rwkv6_time_mix(p["time_mix"], cfg, h, ctx=ctx,
                                                state=st["time_mix"])
        x = x + tm
        h = rmsnorm(p["post_tm_norm"], x)
        with ctx.scope("channel_mix"):
            cm, new_cm = ssm_mod.rwkv6_channel_mix(p["channel_mix"], cfg, h,
                                                   ctx=ctx,
                                                   state=st["channel_mix"])
        x = x + cm
        return x, aux, {"time_mix": new_tm, "channel_mix": new_cm}
    if kind == "mamba":
        h = rmsnorm(p["input_norm"], x)
        with ctx.scope("mixer"):
            mo, new_state = ssm_mod.mamba2_forward(p["mixer"], cfg, h, ctx=ctx,
                                                   state=cache)
        return x + mo, aux, new_state
    raise ValueError(kind)


def block_init_cache(cfg: ArchConfig, kind: str, batch, seq_len, dtype):
    if kind in ("attn_mlp", "attn_dense_mlp", "attn_moe", "shared_attn"):
        if cfg.attn == "mla":
            return attn_mod.mla_init_cache(cfg, batch, seq_len, dtype)
        return attn_mod.gqa_init_cache(cfg, batch, seq_len, dtype)
    if kind == "rwkv":
        return ssm_mod.rwkv6_init_state(cfg, batch, dtype)
    if kind == "mamba":
        return ssm_mod.mamba2_init_state(cfg, batch, dtype)
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# Model
# ---------------------------------------------------------------------------

class Model:
    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg
        self.plan = build_plan(cfg)
        self.dtype = jnp.dtype(cfg.param_dtype)
        self.cdtype = jnp.dtype(cfg.compute_dtype)

    # ---- init ---------------------------------------------------------------
    def init(self, rng) -> dict:
        cfg = self.cfg
        keys = jax.random.split(rng, len(self.plan) + 4)
        params = {"embedding": {"word_embeddings":
                                embed_init(keys[0], cfg.vocab, cfg.d_model,
                                           self.dtype)},
                  "final_norm": jnp.ones((cfg.d_model,), self.dtype)}
        if not cfg.tie_embeddings:
            params["lm_head"] = embed_init(keys[1], cfg.vocab, cfg.d_model,
                                           self.dtype)
        if cfg.arch_type == "vlm":
            params["vision_proj"] = linear_init(keys[2], cfg.vision_dim,
                                                cfg.d_model, self.dtype,
                                                bias=True)
        if cfg.arch_type == "audio":
            params["audio_proj"] = linear_init(keys[2], cfg.audio_dim,
                                               cfg.d_model, self.dtype,
                                               bias=True)
            params["mask_embed"] = (0.02 * jax.random.normal(
                keys[3], (cfg.d_model,), jnp.float32)).astype(self.dtype)
        shared_done = False
        for seg, k in zip(self.plan, keys[4:]):
            if seg.shared:
                if not shared_done:
                    params["shared_attn"] = block_init(k, cfg, "shared_attn",
                                                       self.dtype)
                    shared_done = True
                continue
            if seg.scan:
                sub = jax.random.split(k, seg.n)
                params[seg.name] = jax.vmap(
                    lambda kk: block_init(kk, cfg, seg.kind, self.dtype))(sub)
            elif seg.n == 1:
                params[seg.name] = [block_init(k, cfg, seg.kind, self.dtype)]
            else:
                sub = jax.random.split(k, seg.n)
                params[seg.name] = [block_init(kk, cfg, seg.kind, self.dtype)
                                    for kk in sub]
        return params

    # ---- embedding / head ----------------------------------------------------
    def embed(self, params, batch, ctx=None):
        cfg = self.cfg
        ctx = ensure_ctx(ctx)
        with ctx.scope("embedding"):
            if cfg.arch_type == "audio":
                feats = batch["features"].astype(self.cdtype)
                h = linear(params["audio_proj"], feats)
                if "mask" in batch:
                    m = batch["mask"][..., None].astype(self.cdtype)
                    h = h * (1 - m) + params["mask_embed"].astype(self.cdtype) * m
            else:
                tok = params["embedding"]["word_embeddings"]
                h = tok[batch["tokens"]].astype(self.cdtype)
                if cfg.arch_type == "vlm" and "image_embeds" in batch:
                    img = linear(params["vision_proj"],
                                 batch["image_embeds"].astype(self.cdtype))
                    h = jnp.concatenate([img, h], axis=1)
            h = ctx.tap("output", h)
        h = constrain(h, "btd")
        return h

    def unembed(self, params, h):
        e = (params["embedding"]["word_embeddings"]
             if self.cfg.tie_embeddings else params["lm_head"])
        return _logits(h, e)

    # ---- forward --------------------------------------------------------------
    def apply_blocks(self, params, h, ctx=None, caches=None, pos=None,
                     decode=False, use_kernel=False, precision=None):
        cfg = self.cfg
        ctx = ensure_ctx(ctx)
        aux_total = jnp.zeros((), jnp.float32)
        new_caches = {}
        for seg in self.plan:
            cache = None if caches is None else caches.get(seg.name)
            p_seg = params["shared_attn"] if seg.shared else params[seg.name]
            if seg.shared or not seg.scan:
                blocks = [p_seg] if seg.shared else p_seg
                ncs = []
                for j, bp in enumerate(blocks):
                    li = seg.layer0 + j
                    scope = (f"{seg.name}" if seg.shared else f"layers.{li}")
                    bc = None if cache is None else cache[j]
                    with ctx.scope(scope):
                        h, aux, nc = block_apply(
                            bp, cfg, seg.kind, h, ctx, cache=bc, pos=pos,
                            decode=decode, use_kernel=use_kernel,
                            precision=precision)
                    h = constrain(h, "btd")
                    aux_total += aux
                    ncs.append(nc)
                new_caches[seg.name] = ncs
            else:
                def body(carry, xs):
                    hh, aux_c = carry
                    bp, bc = xs
                    hh, aux, nc = block_apply(bp, cfg, seg.kind, hh, None,
                                              cache=bc, pos=pos, decode=decode,
                                              use_kernel=use_kernel,
                                              precision=precision)
                    # note: no sharding constraint here — inside a rematted
                    # scan body the constrained copy of the carry would be
                    # saved ALONGSIDE the carry itself (2x activation saves);
                    # the carry inherits its layout from the scan entry.
                    return (hh, aux_c + aux), nc
                if cfg.remat and cfg.remat_policy == "dots":
                    fn = jax.checkpoint(
                        body, policy=jax.checkpoint_policies
                        .dots_with_no_batch_dims_saveable)
                elif cfg.remat:
                    fn = jax.checkpoint(body)
                else:
                    fn = body
                (h, aux_total), ncs = jax.lax.scan(
                    fn, (h, aux_total), (p_seg, cache))
                new_caches[seg.name] = ncs
        h = rmsnorm(params["final_norm"], h)
        h = ctx.tap("final_norm_out", h) if ctx.mode != "off" else h
        return h, aux_total, new_caches

    def forward(self, params, batch, ctx=None, use_kernel=False,
                precision=None):
        h = self.embed(params, batch, ctx)
        h, aux, _ = self.apply_blocks(params, h, ctx, use_kernel=use_kernel,
                                      precision=precision)
        return h, aux

    # ---- loss -------------------------------------------------------------------
    def loss(self, params, batch, ctx=None, use_kernel=False, precision=None):
        cfg = self.cfg
        h, aux = self.forward(params, batch, ctx, use_kernel=use_kernel,
                              precision=precision)
        e = (params["embedding"]["word_embeddings"]
             if cfg.tie_embeddings else params.get("lm_head"))
        labels = batch["labels"]
        mask = batch.get("loss_mask")
        if cfg.arch_type == "vlm":
            h = h[:, -labels.shape[1]:]          # loss only on text positions
        if cfg.arch_type == "audio":
            mask = batch["mask"]
        big = h.shape[1] * cfg.vocab > (1 << 26) and not COST_MODE
        if big:
            ce = chunked_cross_entropy(h, e, labels, mask=mask,
                                       chunk=min(1024, h.shape[1]))
        else:
            ce = cross_entropy(_logits(h, e), labels, mask=mask)
        loss = ce + aux
        return loss, {"ce": ce, "aux": aux}

    # ---- decode -------------------------------------------------------------------
    def init_cache(self, batch, seq_len, dtype=None):
        cfg = self.cfg
        dtype = dtype or self.cdtype
        caches = {}
        for seg in self.plan:
            if seg.shared:
                caches[seg.name] = [block_init_cache(cfg, "shared_attn", batch,
                                                     seq_len, dtype)]
            elif seg.scan:
                one = block_init_cache(cfg, seg.kind, batch, seq_len, dtype)
                caches[seg.name] = jax.tree.map(
                    lambda x: jnp.broadcast_to(x[None], (seg.n,) + x.shape),
                    one)
            else:
                caches[seg.name] = [block_init_cache(cfg, seg.kind, batch,
                                                     seq_len, dtype)
                                    for _ in range(seg.n)]
        return caches

    def decode_step(self, params, caches, tokens, pos, ctx=None):
        """tokens: (B,1) int32; pos: scalar int32.  Returns (logits, caches)."""
        batch = {"tokens": tokens}
        h = self.embed(params, batch, ctx)
        h, _, new_caches = self.apply_blocks(params, h, ctx, caches=caches,
                                             pos=pos, decode=True)
        logits = self.unembed(params, h)
        return logits, new_caches
