"""Attention variants: GQA (full / sliding-window / bidirectional), MLA.

Reference semantics in pure jnp.  Long sequences route through a blockwise
(online-softmax) implementation so prefill_32k/long_500k never materialize the
(S x S) score matrix; the Pallas flash kernel (repro/kernels/flash_attention)
is the TPU execution path for the same math and is validated against
``attention_ref`` in interpret mode.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core.tap import ensure_ctx
from repro.models.layers import linear, linear_init, apply_rope, rmsnorm

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Core attention math
# ---------------------------------------------------------------------------

def _mask(mode: str, q_pos, k_pos, window: int):
    """q_pos: (Q,), k_pos: (K,) -> bool (Q,K); True = attend."""
    if mode == "bidirectional":
        return jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    m = k_pos[None, :] <= q_pos[:, None]
    if mode == "swa":
        m &= k_pos[None, :] > (q_pos[:, None] - window)
    return m


def attention_ref(q, k, v, mode="causal", window=0, q_pos=None, k_pos=None):
    """q: (B,Q,H,D), k/v: (B,K,Hkv,D[v]).  Naive reference (materializes scores)."""
    B, Q, H, D = q.shape
    K, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    if q_pos is None:
        q_pos = jnp.arange(Q)
    if k_pos is None:
        k_pos = jnp.arange(K)
    qg = q.reshape(B, Q, Hkv, G, D)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) / jnp.sqrt(D).astype(jnp.float32)
    m = _mask(mode, q_pos, k_pos, window)
    s = jnp.where(m[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return o.reshape(B, Q, H, v.shape[-1]).astype(q.dtype)


# cost-analysis mode: run the two-level flash recurrence as unrolled python
# loops so XLA counts every block's traffic/flops (loop bodies count once)
UNROLL_BLOCKWISE = False


def attention_blockwise(q, k, v, mode="causal", window=0, q_block=512,
                        kv_block=512):
    """Flash-style two-level scan: O(B*H*qb*kb) peak instead of O(S^2)."""
    B, S, H, D = q.shape
    K, Hkv, Dv = k.shape[1], k.shape[2], v.shape[-1]
    G = H // Hkv
    assert S % q_block == 0 and K % kv_block == 0, (S, K, q_block, kv_block)
    nq, nk = S // q_block, K // kv_block
    scale = 1.0 / jnp.sqrt(D).astype(jnp.float32)

    qb = q.reshape(B, nq, q_block, Hkv, G, D).transpose(1, 0, 3, 4, 2, 5)
    # (nq, B, Hkv, G, qb, D)
    kb = k.reshape(B, nk, kv_block, Hkv, D).transpose(1, 0, 3, 2, 4)
    vb = v.reshape(B, nk, kv_block, Hkv, Dv).transpose(1, 0, 3, 2, 4)

    def q_step(_, qi_x):
        qi, qx = qi_x
        qx = qx.astype(jnp.float32)
        q_pos = qi * q_block + jnp.arange(q_block)

        def kv_step(carry, ki_kv):
            m_run, l_run, acc = carry
            ki, kx, vx = ki_kv
            k_pos = ki * kv_block + jnp.arange(kv_block)
            s = jnp.einsum("bhgqd,bhkd->bhgqk", qx,
                           kx.astype(jnp.float32)) * scale
            msk = _mask(mode, q_pos, k_pos, window)
            s = jnp.where(msk[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m_run, jnp.max(s, axis=-1))
            alpha = jnp.exp(m_run - m_new)
            p = jnp.exp(s - m_new[..., None])
            l_new = l_run * alpha + jnp.sum(p, axis=-1)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bhgqk,bhkd->bhgqd", p, vx.astype(jnp.float32))
            return (m_new, l_new, acc), None

        init = (jnp.full((B, Hkv, G, q_block), NEG_INF, jnp.float32),
                jnp.zeros((B, Hkv, G, q_block), jnp.float32),
                jnp.zeros((B, Hkv, G, q_block, Dv), jnp.float32))
        # remat the kv step: the (qb, kb) score/softmax blocks are recomputed
        # in the backward instead of being saved per scan step (the O(S^2)
        # memory this blockwise form exists to avoid)
        if UNROLL_BLOCKWISE:
            carry = init
            for ki in range(nk):
                carry, _ = jax.checkpoint(kv_step)(
                    carry, (jnp.int32(ki), kb[ki], vb[ki]))
            m_run, l_run, acc = carry
        else:
            (m_run, l_run, acc), _ = jax.lax.scan(
                jax.checkpoint(kv_step), init, (jnp.arange(nk), kb, vb))
        out = acc / jnp.maximum(l_run, 1e-30)[..., None]
        # cast per q-block: the stacked output accumulates in the compute
        # dtype, halving the O(B*S*H*D) fp32 transient
        return None, out.transpose(0, 3, 1, 2, 4).astype(q.dtype)

    if UNROLL_BLOCKWISE:
        ob = jnp.stack([q_step(None, (jnp.int32(qi), qb[qi]))[1]
                        for qi in range(nq)])
    else:
        _, ob = jax.lax.scan(q_step, None, (jnp.arange(nq), qb))
    # ob: (nq, B, qb, Hkv, G, Dv)
    return (ob.transpose(1, 0, 2, 3, 4, 5)
            .reshape(B, S, H, Dv))


# Cost-model escape hatch: XLA's cost_analysis counts loop bodies once, so
# the roofline benchmark forces the scan-free naive path (same matmul
# semantics, fully unrolled HLO).
FORCE_NAIVE = False


def attention(q, k, v, mode="causal", window=0, blockwise_threshold=2048,
              use_kernel=False):
    if UNROLL_BLOCKWISE and q.shape[1] == k.shape[1] and q.shape[1] >= 1024:
        return attention_blockwise(q, k, v, mode=mode, window=window)
    if FORCE_NAIVE:
        return attention_ref(q, k, v, mode=mode, window=window)
    if use_kernel:
        from repro.kernels import ops as kops
        return kops.flash_attention(q, k, v, mode=mode, window=window)
    if q.shape[1] == k.shape[1] and q.shape[1] > blockwise_threshold:
        return attention_blockwise(q, k, v, mode=mode, window=window)
    return attention_ref(q, k, v, mode=mode, window=window)


# ---------------------------------------------------------------------------
# GQA module (fused linear_qkv, Megatron naming so paper annotations map 1:1)
# ---------------------------------------------------------------------------

def gqa_init(rng, cfg: ArchConfig, dtype, out_scale=None):
    H, Hkv, D = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    k1, k2, k3, k4 = jax.random.split(rng, 4)
    p = {
        "linear_qkv": linear_init(k1, cfg.d_model, (H + 2 * Hkv) * D, dtype,
                                  bias=cfg.qkv_bias),
        "linear_proj": linear_init(k2, H * D, cfg.d_model, dtype,
                                   scale=out_scale),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((D,), dtype)
        p["k_norm"] = jnp.ones((D,), dtype)
    return p


def _gqa_qkv(p, cfg: ArchConfig, x, positions):
    B, S, _ = x.shape
    H, Hkv, D = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    qkv = linear(p["linear_qkv"], x)
    q, k, v = jnp.split(qkv, [H * D, (H + Hkv) * D], axis=-1)
    q = q.reshape(B, S, H, D)
    k = k.reshape(B, S, Hkv, D)
    v = v.reshape(B, S, Hkv, D)
    if cfg.qk_norm:
        q = rmsnorm(p["q_norm"], q)
        k = rmsnorm(p["k_norm"], k)
    if cfg.attn != "none" and cfg.arch_type != "audio":
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def gqa_forward(p, cfg: ArchConfig, x, positions=None, ctx=None,
                use_kernel=False):
    ctx = ensure_ctx(ctx)
    x = ctx.tap("input", x)
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    q, k, v = _gqa_qkv(p, cfg, x, positions)
    mode = ("bidirectional" if not cfg.causal
            else ("swa" if cfg.attn == "swa" else "causal"))
    o = attention(q, k, v, mode=mode, window=cfg.window, use_kernel=use_kernel)
    o = ctx.tap("core_attn_out", o.reshape(B, S, -1))
    y = linear(p["linear_proj"], o)
    return ctx.tap("output", y)


# ---- decode (one token, KV cache) -----------------------------------------

def gqa_init_cache(cfg: ArchConfig, batch, seq_len, dtype):
    Hkv, D = cfg.n_kv_heads, cfg.d_head
    L = seq_len if cfg.attn != "swa" else min(seq_len, cfg.window)
    return {"k": jnp.zeros((batch, L, Hkv, D), dtype),
            "v": jnp.zeros((batch, L, Hkv, D), dtype)}


def gqa_decode(p, cfg: ArchConfig, x, cache, pos):
    """x: (B,1,d_model); pos: scalar int32 (next position).  SWA caches are
    ring buffers of size ``window``; softmax permutation-invariance makes the
    slot order irrelevant once positions are encoded in the roped keys."""
    B = x.shape[0]
    positions = jnp.broadcast_to(pos, (B, 1))
    q, k_new, v_new = _gqa_qkv(p, cfg, x, positions)
    Lc = cache["k"].shape[1]
    slot = pos % Lc
    k = jax.lax.dynamic_update_slice(cache["k"], k_new.astype(cache["k"].dtype),
                                     (0, slot, 0, 0))
    v = jax.lax.dynamic_update_slice(cache["v"], v_new.astype(cache["v"].dtype),
                                     (0, slot, 0, 0))
    idx = jnp.arange(Lc)
    if cfg.attn == "swa":
        valid = (idx <= slot) | (pos >= Lc)      # ring buffer occupancy
    else:
        valid = idx <= pos
    H, Hkv, D = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    G = H // Hkv
    qg = q.reshape(B, 1, Hkv, G, D)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) / jnp.sqrt(D).astype(jnp.float32)
    s = jnp.where(valid[None, None, None, None, :], s, NEG_INF)
    pw = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", pw, v.astype(jnp.float32))
    o = o.reshape(B, 1, H * D).astype(x.dtype)
    y = linear(p["linear_proj"], o)
    return y, {"k": k, "v": v}


# ---------------------------------------------------------------------------
# Multi-head Latent Attention (DeepSeek-V2)
# ---------------------------------------------------------------------------

def mla_init(rng, cfg: ArchConfig, dtype, out_scale=None):
    m = cfg.mla
    H = cfg.n_heads
    ks = jax.random.split(rng, 8)
    p = {}
    dq = m.qk_nope_dim + m.qk_rope_dim
    if m.q_lora_rank:
        p["linear_dq"] = linear_init(ks[0], cfg.d_model, m.q_lora_rank, dtype)
        p["q_lora_norm"] = jnp.ones((m.q_lora_rank,), dtype)
        p["linear_uq"] = linear_init(ks[1], m.q_lora_rank, H * dq, dtype)
    else:
        p["linear_q"] = linear_init(ks[1], cfg.d_model, H * dq, dtype)
    p["linear_dkv"] = linear_init(ks[2], cfg.d_model, m.kv_lora_rank, dtype)
    p["kv_lora_norm"] = jnp.ones((m.kv_lora_rank,), dtype)
    p["linear_krope"] = linear_init(ks[3], cfg.d_model, m.qk_rope_dim, dtype)
    p["linear_uk"] = linear_init(ks[4], m.kv_lora_rank, H * m.qk_nope_dim, dtype)
    p["linear_uv"] = linear_init(ks[5], m.kv_lora_rank, H * m.v_head_dim, dtype)
    p["linear_proj"] = linear_init(ks[6], H * m.v_head_dim, cfg.d_model, dtype,
                                   scale=out_scale)
    return p


def _mla_q(p, cfg, x, positions):
    m, H = cfg.mla, cfg.n_heads
    B, S, _ = x.shape
    if m.q_lora_rank:
        ql = rmsnorm(p["q_lora_norm"], linear(p["linear_dq"], x))
        q = linear(p["linear_uq"], ql)
    else:
        q = linear(p["linear_q"], x)
    q = q.reshape(B, S, H, m.qk_nope_dim + m.qk_rope_dim)
    q_nope, q_rope = jnp.split(q, [m.qk_nope_dim], axis=-1)
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _mla_ckv(p, cfg, x, positions):
    m = cfg.mla
    ckv = rmsnorm(p["kv_lora_norm"], linear(p["linear_dkv"], x))  # (B,S,r)
    k_rope = linear(p["linear_krope"], x)                          # (B,S,dr)
    k_rope = apply_rope(k_rope, positions, cfg.rope_theta)
    return ckv, k_rope


def mla_forward(p, cfg: ArchConfig, x, positions=None, ctx=None):
    """Training/prefill path: materializes per-head K/V from the latent."""
    ctx = ensure_ctx(ctx)
    x = ctx.tap("input", x)
    m, H = cfg.mla, cfg.n_heads
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    q_nope, q_rope = _mla_q(p, cfg, x, positions)
    ckv, k_rope = _mla_ckv(p, cfg, x, positions)
    k_nope = linear(p["linear_uk"], ckv).reshape(B, S, H, m.qk_nope_dim)
    v = linear(p["linear_uv"], ckv).reshape(B, S, H, m.v_head_dim)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                  (B, S, H, m.qk_rope_dim))], axis=-1)
    o = attention(q, k, v, mode="causal")
    o = ctx.tap("core_attn_out", o.reshape(B, S, -1))
    y = linear(p["linear_proj"], o)
    return ctx.tap("output", y)


def mla_init_cache(cfg: ArchConfig, batch, seq_len, dtype):
    m = cfg.mla
    return {"ckv": jnp.zeros((batch, seq_len, m.kv_lora_rank), dtype),
            "krope": jnp.zeros((batch, seq_len, m.qk_rope_dim), dtype)}


def mla_decode_naive(p, cfg: ArchConfig, x, cache, pos):
    """Naive MLA decode: materializes per-head K/V from the latent cache and
    runs standard attention.  Mathematically identical to ``mla_decode`` —
    an independent implementation used as the inference-TTrace reference."""
    m, H = cfg.mla, cfg.n_heads
    B = x.shape[0]
    positions = jnp.broadcast_to(pos, (B, 1))
    q_nope, q_rope = _mla_q(p, cfg, x, positions)
    ckv_new, krope_new = _mla_ckv(p, cfg, x, positions)
    ckv = jax.lax.dynamic_update_slice(
        cache["ckv"], ckv_new.astype(cache["ckv"].dtype), (0, pos, 0))
    krope = jax.lax.dynamic_update_slice(
        cache["krope"], krope_new.astype(cache["krope"].dtype), (0, pos, 0))
    B_, S = ckv.shape[0], ckv.shape[1]
    k_nope = linear(p["linear_uk"], ckv).reshape(B_, S, H, m.qk_nope_dim)
    v = linear(p["linear_uv"], ckv).reshape(B_, S, H, m.v_head_dim)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(krope[:, :, None, :],
                                  (B_, S, H, m.qk_rope_dim))], axis=-1)
    G = 1
    s_ = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                    k.astype(jnp.float32)) / jnp.sqrt(
        m.qk_nope_dim + m.qk_rope_dim).astype(jnp.float32)
    valid = jnp.arange(S) <= pos
    s_ = jnp.where(valid[None, None, None, :], s_, NEG_INF)
    pw = jax.nn.softmax(s_, axis=-1)
    o = jnp.einsum("bhqk,bkhd->bqhd", pw, v.astype(jnp.float32))
    o = o.reshape(B_, 1, H * m.v_head_dim).astype(x.dtype)
    y = linear(p["linear_proj"], o)
    return y, {"ckv": ckv, "krope": krope}


# inference-TTrace switches (set per decode runner; trace-time globals)
MLA_DECODE_IMPL = "absorbed"         # "absorbed" | "naive"
MLA_DECODE_BUGS: frozenset = frozenset()


def mla_decode(p, cfg, x, cache, pos):
    """Dispatcher: absorbed (production) vs naive (independent reference)
    MLA decode — the two sides of the inference differential test."""
    if MLA_DECODE_IMPL == "naive":
        return mla_decode_naive(p, cfg, x, cache, pos)
    return mla_decode_absorbed(p, cfg, x, cache, pos,
                               bugs=MLA_DECODE_BUGS)


def mla_decode_absorbed(p, cfg: ArchConfig, x, cache, pos, bugs=frozenset()):
    """Absorbed decode: attention runs in the kv_lora latent space, so the
    cache stores only (kv_lora + rope_dim) per token — MLA's memory win.

    ``decode_stale_rope_pos`` (serving-bug analogue of the paper's W-CP
    class): the query rope uses a stale position counter (pos-1) — decoding
    continues silently with slightly wrong attention geometry."""
    m, H = cfg.mla, cfg.n_heads
    B = x.shape[0]
    positions = jnp.broadcast_to(pos, (B, 1))
    qpos = (jnp.maximum(positions - 1, 0)
            if "decode_stale_rope_pos" in bugs else positions)
    q_nope, q_rope = _mla_q(p, cfg, x, qpos)               # (B,1,H,*)
    ckv_new, krope_new = _mla_ckv(p, cfg, x, positions)
    ckv = jax.lax.dynamic_update_slice(
        cache["ckv"], ckv_new.astype(cache["ckv"].dtype), (0, pos, 0))
    krope = jax.lax.dynamic_update_slice(
        cache["krope"], krope_new.astype(cache["krope"].dtype), (0, pos, 0))
    S = ckv.shape[1]
    wuk = p["linear_uk"]["w"].reshape(m.kv_lora_rank, H, m.qk_nope_dim)
    # absorb W_uk into q:   q_lat[b,h,r] = sum_d q_nope[b,h,d] * wuk[r,h,d]
    q_lat = jnp.einsum("bqhd,rhd->bqhr", q_nope.astype(jnp.float32),
                       wuk.astype(jnp.float32))
    s = (jnp.einsum("bqhr,bkr->bhqk", q_lat, ckv.astype(jnp.float32))
         + jnp.einsum("bqhd,bkd->bhqk", q_rope.astype(jnp.float32),
                      krope.astype(jnp.float32)))
    s = s / jnp.sqrt(m.qk_nope_dim + m.qk_rope_dim).astype(jnp.float32)
    valid = jnp.arange(S) <= pos
    s = jnp.where(valid[None, None, None, :], s, NEG_INF)
    pw = jax.nn.softmax(s, axis=-1)
    ctx_lat = jnp.einsum("bhqk,bkr->bqhr", pw, ckv.astype(jnp.float32))
    wuv = p["linear_uv"]["w"].reshape(m.kv_lora_rank, H, m.v_head_dim)
    o = jnp.einsum("bqhr,rhd->bqhd", ctx_lat, wuv.astype(jnp.float32))
    o = o.reshape(B, 1, H * m.v_head_dim).astype(x.dtype)
    y = linear(p["linear_proj"], o)
    return y, {"ckv": ckv, "krope": krope}
