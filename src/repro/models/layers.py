"""Reference building blocks: norms, RoPE, linears, MLPs, losses.

These are the pure-jnp *single-device reference* implementations (the trusted
side of TTrace's differential test).  Distributed candidates live in
``repro/parallel`` (manual collectives) and ``repro/sharding`` (GSPMD rules).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.tap import ensure_ctx

Params = dict


# ---------------------------------------------------------------------------
# Initialization
# ---------------------------------------------------------------------------

def dense_init(rng, d_in, d_out, dtype, scale=None):
    scale = 0.02 if scale is None else scale
    return (scale * jax.random.normal(rng, (d_in, d_out), jnp.float32)).astype(dtype)


def embed_init(rng, vocab, d_model, dtype):
    return (0.02 * jax.random.normal(rng, (vocab, d_model), jnp.float32)).astype(dtype)


# ---------------------------------------------------------------------------
# Primitive layers
# ---------------------------------------------------------------------------

def rmsnorm(w, x, eps=1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * w.astype(jnp.float32)).astype(dt)


def linear(p, x):
    y = x @ p["w"].astype(x.dtype)
    if "b" in p:
        y = y + p["b"].astype(x.dtype)
    return y


def linear_init(rng, d_in, d_out, dtype, bias=False, scale=None):
    p = {"w": dense_init(rng, d_in, d_out, dtype, scale)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def swiglu_mlp_init(rng, d_model, d_ff, dtype, out_scale=None):
    k1, k2, k3 = jax.random.split(rng, 3)
    return {
        "gate": linear_init(k1, d_model, d_ff, dtype),
        "up": linear_init(k2, d_model, d_ff, dtype),
        "down": linear_init(k3, d_ff, d_model, dtype, scale=out_scale),
    }


def _mlp_linear(precision):
    """The matmul the MLPs use: plain, or FP8-quantized per the recipe."""
    if precision is None or not precision.fp8_recipe:
        return linear
    from repro.precision.fp8 import fp8_linear

    def lin(p, x):
        return fp8_linear(p, x, recipe=precision.fp8_recipe,
                          stale_scale=precision.stale_scale,
                          use_kernel=precision.use_kernel)

    return lin


def swiglu_mlp(p, x, ctx=None, precision=None):
    ctx = ensure_ctx(ctx)
    lin = _mlp_linear(precision)
    x = ctx.tap("input", x)
    h = jax.nn.silu(lin(p["gate"], x)) * lin(p["up"], x)
    y = lin(p["down"], h)
    return ctx.tap("output", y)


def gelu_mlp_init(rng, d_model, d_ff, dtype, out_scale=None):
    k1, k2 = jax.random.split(rng)
    return {
        "fc1": linear_init(k1, d_model, d_ff, dtype, bias=True),
        "fc2": linear_init(k2, d_ff, d_model, dtype, bias=True, scale=out_scale),
    }


def gelu_mlp(p, x, ctx=None, precision=None):
    ctx = ensure_ctx(ctx)
    lin = _mlp_linear(precision)
    x = ctx.tap("input", x)
    h = jax.nn.gelu(lin(p["fc1"], x))
    y = lin(p["fc2"], h)
    return ctx.tap("output", y)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope_freqs(d: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (np.arange(0, d, 2, dtype=np.float32) / d))


def apply_rope(x, positions, theta):
    """x: (..., S, H, D) or (..., S, D); positions: (..., S)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, d/2)
    if x.ndim == angles.ndim + 1:          # (..., S, H, D): broadcast over H
        angles = angles[..., None, :]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = x[..., 0::2], x[..., 1::2]
    dt = x.dtype
    x1, x2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.stack([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.reshape(x.shape).astype(dt)


# ---------------------------------------------------------------------------
# Losses
# ---------------------------------------------------------------------------

def cross_entropy(logits, labels, mask=None):
    """Mean next-token CE.  logits: (B,S,V) any float; labels: (B,S) int."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def chunked_cross_entropy(h, embed, labels, mask=None, chunk=512,
                          scale=None):
    """CE computed from hidden states without materializing (B,S,V) logits.

    ``h``: (B,S,D) final hidden states; ``embed``: (V,D) output embedding.
    Scans over sequence chunks so peak memory is O(B*chunk*V).  Used by the
    big dry-run configs where the full logit tensor would dominate HBM.
    """
    B, S, D = h.shape
    if S % chunk != 0:
        return cross_entropy(_logits(h, embed, scale), labels, mask)
    n = S // chunk
    hc = h.reshape(B, n, chunk, D).swapaxes(0, 1)           # (n,B,c,D)
    lc = labels.reshape(B, n, chunk).swapaxes(0, 1)         # (n,B,c)
    mc = (mask.reshape(B, n, chunk).swapaxes(0, 1).astype(jnp.float32)
          if mask is not None else jnp.ones((n, B, chunk), jnp.float32))

    def body(carry, xs):
        hs, ls, ms = xs
        logits = _logits(hs, embed, scale).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, ls[..., None], axis=-1)[..., 0]
        nll = jnp.sum((lse - gold) * ms)
        tot, cnt = carry
        return (tot + nll, cnt + jnp.sum(ms)), None

    # remat: recompute each chunk's logits in the backward pass rather than
    # saving (B, chunk, V) per scan step
    (tot, cnt), _ = jax.lax.scan(jax.checkpoint(body),
                                 (jnp.zeros(()), jnp.zeros(())),
                                 (hc, lc, mc))
    return tot / jnp.maximum(cnt, 1.0)


def _logits(h, embed, scale=None):
    logits = h @ embed.T.astype(h.dtype)
    if scale is not None:
        logits = logits * scale
    return logits
