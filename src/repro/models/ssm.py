"""SSM / linear-attention blocks: Mamba2 (SSD) and RWKV-6 "Finch".

Both are instances of a gated linear-attention recurrence over per-head state
S in R^{dk x dv}:

    S_t = diag(w_t) . S_{t-1} + k_t v_t^T
    y_t = q_t . S_t                         (mamba2 convention), or
    y_t = q_t . (S_{t-1} + diag(u) k_t v_t^T)   (rwkv6 convention)

Mamba2 uses a scalar per-head decay (w_t = exp(-exp(A_log) * dt_t)); RWKV-6
uses a data-dependent per-channel decay (Finch).  We implement

* ``lin_attn_recurrent`` — step-by-step lax.scan; the numerical oracle and the
  decode path (one step per token);
* ``lin_attn_chunked``   — chunked parallel form (GLA-style): O(S/C) sequential
  steps of dense matmuls, the training/prefill path and the contract for the
  Pallas kernel (repro/kernels/ssm_scan).  Intra-chunk decays are factorized
  as (q*exp(L)) @ (k*exp(-L))^T with L clamped at -CLAMP, the standard "safe
  gate" trick (cf. flash-linear-attention); the clamp only touches channels
  already decayed to ~exp(-20).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core.tap import ensure_ctx
from repro.models.layers import linear, linear_init, dense_init, rmsnorm

CLAMP = 20.0

# benchmarks/roofline sets this: run the chunk recurrence as an unrolled
# python loop so XLA's cost analysis (which counts loop bodies once) sees
# every chunk of the production-size chunked scan.
UNROLL_SCAN = False


# ---------------------------------------------------------------------------
# Generic decayed linear attention
# ---------------------------------------------------------------------------

def lin_attn_recurrent(q, k, v, log_w, u=None, s0=None):
    """q,k:(B,S,H,dk) v:(B,S,H,dv) log_w:(B,S,H,dk) (log decay, <=0).

    Returns y:(B,S,H,dv), s_final:(B,H,dk,dv).  ``u``:(H,dk) switches to the
    rwkv convention (bonus on the current token, decay applied after read)."""
    B, S, H, dk = q.shape
    dv = v.shape[-1]
    if s0 is None:
        s0 = jnp.zeros((B, H, dk, dv), jnp.float32)

    def step(s, xs):
        qt, kt, vt, lwt = xs  # (B,H,dk) etc
        wt = jnp.exp(lwt.astype(jnp.float32))[..., None]       # (B,H,dk,1)
        kv = kt.astype(jnp.float32)[..., None] * vt.astype(jnp.float32)[..., None, :]
        if u is None:
            s = wt * s + kv
            y = jnp.einsum("bhk,bhkv->bhv", qt.astype(jnp.float32), s)
        else:
            y = jnp.einsum("bhk,bhkv->bhv", qt.astype(jnp.float32),
                           s + u.astype(jnp.float32)[None, :, :, None] * kv)
            s = wt * s + kv
        return s, y

    xs = tuple(x.swapaxes(0, 1) for x in (q, k, v, log_w))
    s_fin, ys = jax.lax.scan(step, s0, xs)
    return ys.swapaxes(0, 1).astype(v.dtype), s_fin


def lin_attn_chunked(q, k, v, log_w, chunk=128, u=None, s0=None):
    """Chunked parallel form; same contract as ``lin_attn_recurrent``.

    ``log_w`` may be (B,S,H,dk) (per-channel decay, rwkv6) or (B,S,H,1)
    (scalar per-head decay, mamba2).  The scalar case uses the exact
    exp(L_t - L_s) relative-decay matrix (SSD "segsum" form) — no clamping;
    the per-channel case uses the clamped "safe gate" factorization, exact
    whenever per-chunk cumulative decay stays above -CLAMP (true for RWKV-6's
    bounded decays)."""
    B, S, H, dk = q.shape
    dv = v.shape[-1]
    scalar = log_w.shape[-1] == 1
    if S % chunk != 0:
        return lin_attn_recurrent(q, k, v, log_w, u=u, s0=s0)
    n = S // chunk
    if s0 is None:
        s0 = jnp.zeros((B, H, dk, dv), jnp.float32)

    def split(x):  # (B,S,H,*) -> (n,B,H,C,*)
        return x.reshape(B, n, chunk, H, -1).transpose(1, 0, 3, 2, 4)

    qs, ks, vs, lws = (split(x).astype(jnp.float32) for x in (q, k, v, log_w))
    causal = jnp.tril(jnp.ones((chunk, chunk), bool),
                      0 if u is None else -1)

    def body(s, xs):
        qc, kc, vc, lw = xs                       # (B,H,C,dk|dv)
        L = jnp.cumsum(lw, axis=2)                # inclusive log-decay
        Lq = L if u is None else L - lw           # rwkv reads S_{t-1}
        q_t = qc * jnp.exp(Lq)
        if scalar:
            # exact relative decay exp(Lq_t - L_s), scalar per head
            D = jnp.exp(jnp.clip(Lq[..., 0][..., :, None]
                                 - L[..., 0][..., None, :], None, 0.0))
            A = jnp.einsum("bhtk,bhsk->bhts", qc, kc) * D
        else:
            k_t = kc * jnp.exp(-jnp.maximum(L, -CLAMP))
            A = jnp.einsum("bhtk,bhsk->bhts", q_t, k_t)
        A = jnp.where(causal[None, None], A, 0.0)
        y = jnp.einsum("bhts,bhsv->bhtv", A, vc)          # intra-chunk
        y += jnp.einsum("bhtk,bhkv->bhtv", q_t, s)        # inter-chunk
        # (rwkv current-token bonus is added outside the scan)
        # state update: S' = exp(L_C) . S + sum_s exp(L_C - L_s) k_s v_s^T
        Lc = L[:, :, -1:, :]                               # (B,H,1,dk)
        k_dec = kc * jnp.exp(Lc - L)
        s = jnp.exp(Lc[:, :, 0, :, None]) * s + jnp.einsum(
            "bhsk,bhsv->bhkv", k_dec, vc)
        return s, y

    # rwkv bonus handled separately (cleaner than inside the scan body);
    # remat the chunk body so (C,C) decay/score blocks are recomputed in the
    # backward instead of saved per chunk
    if UNROLL_SCAN:
        s_acc, ys_l = s0, []
        for i in range(n):
            s_acc, yi = body(s_acc, (qs[i], ks[i], vs[i], lws[i]))
            ys_l.append(yi)
        s_fin, ys = s_acc, jnp.stack(ys_l)
    else:
        s_fin, ys = jax.lax.scan(jax.checkpoint(body), s0,
                                 (qs, ks, vs, lws))
    y = ys.transpose(1, 0, 3, 2, 4).reshape(B, S, H, dv)
    if u is not None:
        bonus = jnp.einsum("bshk,hk,bshk->bsh", q.astype(jnp.float32),
                           u.astype(jnp.float32), k.astype(jnp.float32))
        y = y + bonus[..., None] * v.astype(jnp.float32)
    return y.astype(v.dtype), s_fin


def lin_attn(q, k, v, log_w, chunk=128, u=None, s0=None, chunked=True):
    if chunked:
        return lin_attn_chunked(q, k, v, log_w, chunk=chunk, u=u, s0=s0)
    return lin_attn_recurrent(q, k, v, log_w, u=u, s0=s0)


# ---------------------------------------------------------------------------
# Mamba2 block (SSD)
# ---------------------------------------------------------------------------

def mamba2_dims(cfg: ArchConfig):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    n_heads = d_inner // s.d_head
    conv_dim = d_inner + 2 * s.d_state
    return d_inner, n_heads, conv_dim


def mamba2_init(rng, cfg: ArchConfig, dtype, out_scale=None):
    s = cfg.ssm
    d_inner, H, conv_dim = mamba2_dims(cfg)
    ks = jax.random.split(rng, 4)
    proj_dim = 2 * d_inner + 2 * s.d_state + H   # z, x, B, C, dt
    return {
        "in_proj": linear_init(ks[0], cfg.d_model, proj_dim, dtype),
        "conv_w": dense_init(ks[1], s.conv_kernel, conv_dim, dtype, scale=0.2),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.zeros((H,), jnp.float32),           # A = -exp(A_log) = -1
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "gate_norm": jnp.ones((d_inner,), dtype),
        "out_proj": linear_init(ks[2], d_inner, cfg.d_model, dtype,
                                scale=out_scale),
    }


def _causal_conv(w, b, x, state=None):
    """Depthwise causal conv1d.  x:(B,S,C), w:(K,C).  ``state``:(B,K-1,C) are
    the trailing inputs from the previous segment (decode)."""
    K = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], K - 1, x.shape[-1]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * w[i].astype(x.dtype) for i in range(K))
    new_state = xp[:, -(K - 1):] if K > 1 else None
    return y + b.astype(x.dtype), new_state


def mamba2_forward(p, cfg: ArchConfig, x, ctx=None, state=None, chunked=True):
    """x:(B,S,d_model).  ``state``: dict(conv, ssm) for decode continuation.
    Returns (y, new_state)."""
    ctx = ensure_ctx(ctx)
    x = ctx.tap("input", x)
    s = cfg.ssm
    d_inner, H, conv_dim = mamba2_dims(cfg)
    B, S, _ = x.shape
    zxbcdt = linear(p["in_proj"], x)
    z, xin, Bm, Cm, dt = jnp.split(
        zxbcdt, [d_inner, 2 * d_inner, 2 * d_inner + s.d_state,
                 2 * d_inner + 2 * s.d_state], axis=-1)
    # depthwise conv applied per segment: convolving xin/B/C separately is
    # identical to conv(concat(...)) but keeps the (model-)sharded xin
    # sharded — the concat with the replicated B/C otherwise forces a
    # full all-gather of xin every block (EXPERIMENTS.md §Perf, zamba2)
    conv_state = None if state is None else state["conv"]
    outs, new_states = [], []
    off = 0
    for seg_x in (xin, Bm, Cm):
        wseg = p["conv_w"][:, off:off + seg_x.shape[-1]]
        bseg = p["conv_b"][off:off + seg_x.shape[-1]]
        st_seg = (None if conv_state is None
                  else conv_state[..., off:off + seg_x.shape[-1]])
        o, ns = _causal_conv(wseg, bseg, seg_x, st_seg)
        outs.append(jax.nn.silu(o))
        new_states.append(ns)
        off += seg_x.shape[-1]
    xin, Bm, Cm = outs
    new_conv = (None if new_states[0] is None
                else jnp.concatenate(new_states, axis=-1))

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])      # (B,S,H)
    a = -jnp.exp(p["A_log"])                                         # (H,)
    log_w = (dt * a)[..., None]                                      # (B,S,H,1)

    xh = xin.reshape(B, S, H, s.d_head)
    v = xh.astype(jnp.float32) * dt[..., None]                       # dt * x
    q = jnp.broadcast_to(Cm[:, :, None, :], (B, S, H, s.d_state))
    k = jnp.broadcast_to(Bm[:, :, None, :], (B, S, H, s.d_state))

    ssm_state = None if state is None else state["ssm"]
    # scalar per-head decay: (B,S,H,1) selects the exact SSD segsum path
    y, new_ssm = lin_attn(q, k, v.astype(x.dtype), log_w,
                          chunk=s.chunk, s0=ssm_state, chunked=chunked)
    y = y.astype(jnp.float32) + p["D"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(B, S, d_inner).astype(x.dtype)
    y = rmsnorm(p["gate_norm"], y * jax.nn.silu(z))
    out = linear(p["out_proj"], y)
    out = ctx.tap("output", out)
    new_state = {"conv": new_conv, "ssm": new_ssm}
    return out, new_state


def mamba2_init_state(cfg: ArchConfig, batch, dtype):
    s = cfg.ssm
    d_inner, H, conv_dim = mamba2_dims(cfg)
    return {"conv": jnp.zeros((batch, s.conv_kernel - 1, conv_dim), dtype),
            "ssm": jnp.zeros((batch, H, s.d_state, s.d_head), jnp.float32)}


# ---------------------------------------------------------------------------
# RWKV-6 block (time-mix + channel-mix)
# ---------------------------------------------------------------------------

def rwkv6_init(rng, cfg: ArchConfig, dtype, out_scale=None):
    s = cfg.ssm
    d = cfg.d_model
    H = cfg.n_heads
    dh = s.d_head
    ks = jax.random.split(rng, 12)
    tm = {
        "mu_x": 0.5 * jnp.ones((d,), jnp.float32),
        # data-dependent token-shift mixing (Finch): 5 targets r,k,v,w,g
        "mix_A": dense_init(ks[0], d, 5 * s.mix_lora, dtype),
        "mix_B": (0.02 * jax.random.normal(ks[1], (5, s.mix_lora, d),
                                           jnp.float32)).astype(dtype),
        "mu": 0.5 * jnp.ones((5, d), jnp.float32),
        "recept": linear_init(ks[2], d, H * dh, dtype),
        "key": linear_init(ks[3], d, H * dh, dtype),
        "value": linear_init(ks[4], d, H * dh, dtype),
        "gate": linear_init(ks[5], d, H * dh, dtype),
        # data-dependent decay (Finch): w = exp(-exp(w0 + lora(x)))
        "w0": -6.0 + jnp.zeros((H * dh,), jnp.float32),
        "decay_A": dense_init(ks[6], d, s.decay_lora, dtype),
        "decay_B": dense_init(ks[7], s.decay_lora, H * dh, dtype),
        "u": 0.5 * jnp.ones((H, dh), jnp.float32),   # current-token bonus
        "ln_out": jnp.ones((H * dh,), dtype),
        "out": linear_init(ks[8], H * dh, d, dtype, scale=out_scale),
    }
    cm = {
        "mu_k": 0.5 * jnp.ones((d,), jnp.float32),
        "mu_r": 0.5 * jnp.ones((d,), jnp.float32),
        "key": linear_init(ks[9], d, cfg.d_ff, dtype),
        "value": linear_init(ks[10], cfg.d_ff, d, dtype, scale=out_scale),
        "recept": linear_init(ks[11], d, d, dtype),
    }
    return {"time_mix": tm, "channel_mix": cm}


def _token_shift(x, last):
    """last:(B,1,d) trailing token of the previous segment (or zeros)."""
    return jnp.concatenate([last.astype(x.dtype), x[:, :-1]], axis=1)


def rwkv6_time_mix(p, cfg: ArchConfig, x, ctx=None, state=None, chunked=True):
    ctx = ensure_ctx(ctx)
    x = ctx.tap("input", x)
    s = cfg.ssm
    H, dh = cfg.n_heads, s.d_head
    B, S, d = x.shape
    last = (jnp.zeros((B, 1, d), x.dtype) if state is None
            else state["shift"])
    xprev = _token_shift(x, last)
    xx = xprev - x
    xxx = x + xx * p["mu_x"].astype(x.dtype)
    dmix = jnp.tanh(linear({"w": p["mix_A"]}, xxx))
    dmix = dmix.reshape(B, S, 5, s.mix_lora)
    dmix = jnp.einsum("bsfm,fmd->bsfd", dmix.astype(jnp.float32),
                      p["mix_B"].astype(jnp.float32))
    mixes = p["mu"][None, None] + dmix                      # (B,S,5,d)
    xr, xk, xv, xw, xg = [
        (x + xx * mixes[:, :, i].astype(x.dtype)) for i in range(5)]

    r = linear(p["recept"], xr).reshape(B, S, H, dh)
    k = linear(p["key"], xk).reshape(B, S, H, dh)
    v = linear(p["value"], xv).reshape(B, S, H, dh)
    g = linear(p["gate"], xg)
    dlora = jnp.tanh(linear({"w": p["decay_A"]}, xw))
    dw = linear({"w": p["decay_B"]}, dlora).astype(jnp.float32)
    log_w = -jnp.exp(p["w0"][None, None] + dw)              # (B,S,H*dh) <= 0
    log_w = log_w.reshape(B, S, H, dh)

    ssm_state = None if state is None else state["ssm"]
    y, new_ssm = lin_attn(r, k, v, log_w, chunk=s.chunk, u=p["u"],
                          s0=ssm_state, chunked=chunked)
    y = y.reshape(B, S, H * dh)
    # per-head group norm
    yh = y.astype(jnp.float32).reshape(B, S, H, dh)
    yh = (yh - yh.mean(-1, keepdims=True)) * jax.lax.rsqrt(
        yh.var(-1, keepdims=True) + 1e-5)
    y = (yh.reshape(B, S, H * dh) * p["ln_out"].astype(jnp.float32))
    y = (y * jax.nn.silu(g.astype(jnp.float32))).astype(x.dtype)
    out = linear(p["out"], y)
    out = ctx.tap("output", out)
    new_state = {"shift": x[:, -1:], "ssm": new_ssm}
    return out, new_state


def rwkv6_channel_mix(p, cfg: ArchConfig, x, ctx=None, state=None):
    ctx = ensure_ctx(ctx)
    x = ctx.tap("input", x)
    B, S, d = x.shape
    last = (jnp.zeros((B, 1, d), x.dtype) if state is None
            else state["shift"])
    xprev = _token_shift(x, last)
    xx = xprev - x
    xk = x + xx * p["mu_k"].astype(x.dtype)
    xr = x + xx * p["mu_r"].astype(x.dtype)
    k = jnp.square(jax.nn.relu(linear(p["key"], xk)))
    kv = linear(p["value"], k)
    out = jax.nn.sigmoid(linear(p["recept"], xr).astype(jnp.float32)
                         ).astype(x.dtype) * kv
    out = ctx.tap("output", out)
    return out, {"shift": x[:, -1:]}


def rwkv6_init_state(cfg: ArchConfig, batch, dtype):
    s = cfg.ssm
    H, dh = cfg.n_heads, s.d_head
    d = cfg.d_model
    return {
        "time_mix": {"shift": jnp.zeros((batch, 1, d), dtype),
                     "ssm": jnp.zeros((batch, H, dh, dh), jnp.float32)},
        "channel_mix": {"shift": jnp.zeros((batch, 1, d), dtype)},
    }
