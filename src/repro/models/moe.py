"""Mixture-of-Experts: top-k router + capacity-based expert dispatch.

Dispatch uses the sort-based capacity layout (the TPU-idiomatic equivalent of
Megatron's token dropper): token-expert assignments are sorted by expert id,
each expert processes a fixed-capacity (E, C, d) buffer via one batched
matmul, and overflow tokens are dropped (capacity_factor controls C).  FLOPs
therefore match the true MoE cost E*C*d*f ~= T*topk*cf*d*f instead of the
T*(E*C)*d quadratic cost of one-hot dispatch einsums.

Router logits/probs are tapped (paper bug #6 — router weights not synchronized
— surfaces exactly here).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core.tap import ensure_ctx
from repro.models.layers import linear_init, swiglu_mlp_init, dense_init


def moe_init(rng, cfg: ArchConfig, dtype, out_scale=None):
    m = cfg.moe
    ks = jax.random.split(rng, 5)
    E, d, f = m.n_experts, cfg.d_model, m.d_ff_expert
    p = {
        "router": dense_init(ks[0], d, E, jnp.float32),    # fp32 router
        "experts": {
            "gate": (0.02 * jax.random.normal(ks[1], (E, d, f), jnp.float32)
                     ).astype(dtype),
            "up": (0.02 * jax.random.normal(ks[2], (E, d, f), jnp.float32)
                   ).astype(dtype),
            "down": ((out_scale or 0.02)
                     * jax.random.normal(ks[3], (E, f, d), jnp.float32)
                     ).astype(dtype),
        },
    }
    if m.n_shared:
        p["shared"] = swiglu_mlp_init(ks[4], d, m.n_shared * f, dtype,
                                      out_scale=out_scale)
    return p


def router_topk(logits, top_k):
    """fp32 softmax-then-topk with renormalization.  logits: (T, E)."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    top_p, top_e = jax.lax.top_k(probs, top_k)                 # (T,k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
    return top_p, top_e


def load_balance_loss(probs_mean, assigned_frac, n_experts):
    """Switch-style aux loss: E * sum_e f_e * P_e."""
    return n_experts * jnp.sum(probs_mean * assigned_frac)


def expert_capacity(n_tokens: int, m) -> int:
    """Per-expert buffer size; capacity_factor <= 0 means dropless.
    Rounded up to a multiple of 512 so the capacity dim of the (E, C, d)
    dispatch buffer stays divisible by the dp mesh axes (shardable) and
    MXU-tile aligned."""
    if m.capacity_factor <= 0:
        return n_tokens
    cap = int(max(1, m.capacity_factor * n_tokens * m.top_k / m.n_experts))
    if cap > 512:
        cap = -(-cap // 512) * 512
    return cap


def _dispatch_one_group(xt, top_p, top_e, n_experts, top_k, cap, experts,
                        dtype, flat_constraints=False):
    """Capacity dispatch + expert compute + combine for ONE token group.
    All indices are group-local, so under vmap with the group dim sharded
    over the data axes nothing ever gathers across devices.

    ``flat_constraints`` is OFF for both paths after measurement: for the
    ungrouped (non-EP) path the best-known layout is the (E, C/data, d)
    buffer with free flat tensors (60 GiB on mixtral train vs 98 with flat
    sharding: the buf<->flat resharding costs more than it saves, §Perf)."""
    from repro.sharding.rules import constrain
    cf = (lambda t: constrain(t, "flat_tokens")) if flat_constraints \
        else (lambda t: t)
    T, d = xt.shape
    k = top_k
    flat_e = top_e.reshape(T * k)
    flat_w = top_p.reshape(T * k)
    flat_tok = jnp.repeat(jnp.arange(T), k)
    order = jnp.argsort(flat_e, stable=True)
    se, sw, stok = flat_e[order], flat_w[order], flat_tok[order]
    start = jnp.searchsorted(se, jnp.arange(n_experts), side="left")
    pos = jnp.arange(T * k) - start[se]
    keep = pos < cap

    src = cf(jnp.where(keep[:, None], xt[stok], 0.0).astype(dtype))
    buf = jnp.zeros((n_experts, cap, d), dtype)
    buf = buf.at[jnp.where(keep, se, 0), jnp.where(keep, pos, 0)].add(src)
    buf = constrain(buf, "moe_buf" if flat_constraints is None else
                    "vmapped_buf")
    h = (jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf,
                                experts["gate"].astype(dtype)))
         * jnp.einsum("ecd,edf->ecf", buf, experts["up"].astype(dtype)))
    out_buf = jnp.einsum("ecf,efd->ecd", h, experts["down"].astype(dtype))
    gathered = cf(out_buf[jnp.where(keep, se, 0), jnp.where(keep, pos, 0)])
    contrib = cf(jnp.where(keep[:, None],
                           gathered.astype(jnp.float32) * sw[:, None], 0.0))
    yt = cf(jnp.zeros((T, d), jnp.float32).at[stok].add(contrib))
    return yt


def moe_forward(p, cfg: ArchConfig, x, ctx=None):
    """x: (B,S,d).  Returns (y, aux_loss).

    Dispatch runs per token-GROUP (one group per data shard when a sharding
    context is active): capacities, sorts and scatter/gather indices are
    group-local, so GSPMD shards the (G, E, C, d) buffer on (data, model)
    and never replicates the (T*k, d) combine — the deepseek-prefill memory
    cliff documented in EXPERIMENTS.md §Perf."""
    from repro.sharding import rules as shrules
    ctx = ensure_ctx(ctx)
    x = ctx.tap("input", x)
    m = cfg.moe
    B, S, d = x.shape
    T = B * S
    xt = x.reshape(T, d)

    logits = xt.astype(jnp.float32) @ p["router"]              # (T,E) fp32
    logits = ctx.tap("router_logits",
                     logits.reshape(B, S, -1)).reshape(T, -1)
    top_p, top_e = router_topk(logits, m.top_k)

    # aux loss statistics (global)
    probs = jax.nn.softmax(logits, axis=-1)
    assigned = jnp.zeros((m.n_experts,), jnp.float32).at[top_e.reshape(-1)].add(1.0)
    aux = load_balance_loss(probs.mean(0), assigned / (T * m.top_k),
                            m.n_experts) * m.router_aux_coef

    # ---- grouped capacity dispatch ------------------------------------------
    G = shrules.dispatch_groups(T, m.n_experts)
    Tg = T // G
    cap = expert_capacity(Tg, m)
    if G == 1:
        # non-EP path: (E, C/data, d) buffer, unconstrained flat tensors
        yt = _dispatch_one_group(xt, top_p, top_e, m.n_experts, m.top_k,
                                 cap, p["experts"], x.dtype,
                                 flat_constraints=None)[None]
    else:
        disp = jax.vmap(_dispatch_one_group,
                        in_axes=(0, 0, 0, None, None, None, None, None))
        cg = lambda t: shrules.constrain(t, "grouped")
        yt = disp(cg(xt.reshape(G, Tg, d)),
                  cg(top_p.reshape(G, Tg, m.top_k)),
                  cg(top_e.reshape(G, Tg, m.top_k)), m.n_experts, m.top_k,
                  cap, p["experts"], x.dtype)
        yt = shrules.constrain(yt, "grouped")
    y = yt.reshape(B, S, d).astype(x.dtype)

    if m.n_shared:
        from repro.models.layers import swiglu_mlp
        y = y + swiglu_mlp(p["shared"], x)
    y = ctx.tap("output", y)
    return y, aux
