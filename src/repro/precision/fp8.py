"""FP8 (e4m3) training recipes + the stale-scale silent bug (paper §6.7, bug 8).

FP8 matmuls quantize operands to float8_e4m3fn with an amax-derived scale and
accumulate in >= bf16 — so, as the paper observes, the machine epsilon that
governs the *threshold estimation* is still BF16's.  Three scaling recipes
are modelled (paper §6.7):

  * "global":      one scale for the whole tensor (TransformerEngine default)
  * "per_tensor":  alias of global here (per-operand scale)
  * "tile128":     one scale per 128x128 tile (the DeepSeek-V3 recipe) —
                   finer granularity, smaller round-off, as §6.7 predicts.

``fp8_linear`` drops into the reference/parallel MLPs when a ``Precision``
recipe asks for it (``models.layers`` threads it through the model); the
Pallas kernels (repro/kernels/fp8_matmul) are the TPU execution path for the
same math — a plain fp8 matmul with the global scale folded outside, and a
tile-scaled variant that applies the per-128-tile scales inside the K loop.

``make_fp8_train_step`` / ``make_fp8_runner`` are the supervisor-facing
candidate factories: the candidate trains the SAME model with FP8 MLP
matmuls against the full-precision reference, checked under BF16-epsilon
thresholds (§6.7).

Bug 8 ("AR: wrong tensor by FP8 cast"): quantization uses a STALE amax — the
scale of the previous microbatch's tensor — modelled by halving the amax:
values clip, the loss is silently wrong.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp

E4M3_MAX = 448.0
F8 = jnp.float8_e4m3fn
TILE = 128
FP8_RECIPES = ("global", "per_tensor", "tile128")


@dataclass(frozen=True)
class Precision:
    """Numeric recipe threaded through the model MLPs (None = full precision).

    ``stale_scale`` is bug 8's injection point; ``use_kernel`` routes the
    quantized matmul through the Pallas kernels."""
    fp8_recipe: Optional[str] = None
    stale_scale: bool = False
    use_kernel: bool = False

    def __post_init__(self):
        if self.fp8_recipe is not None and self.fp8_recipe not in FP8_RECIPES:
            raise ValueError(f"unknown fp8 recipe {self.fp8_recipe!r}")


def _tile_amax(ax):
    """Per-128x128-tile max of ``ax`` -> compact (..., M/tm, N/tn) array."""
    M, N = ax.shape[-2], ax.shape[-1]
    tm, tn = min(TILE, M), min(TILE, N)
    pm, pn = -M % tm, -N % tn
    axp = jnp.pad(ax, [(0, 0)] * (ax.ndim - 2) + [(0, pm), (0, pn)])
    Mp, Np = axp.shape[-2], axp.shape[-1]
    t = axp.reshape(*axp.shape[:-2], Mp // tm, tm, Np // tn, tn)
    return t.max(axis=(-3, -1))                            # (..., mt, nt)


def expand_tile_scale(scale, shape):
    """Broadcast a compact per-tile scale back to the full operand shape.

    Tiles are the fixed ``min(TILE, dim)`` size ``_tile_amax`` grouped by
    (the LAST tile is the ragged one) — recomputing the size from the tile
    count would shift every boundary on non-128-divisible dims."""
    M, N = shape[-2], shape[-1]
    tm, tn = min(TILE, M), min(TILE, N)
    full = jnp.repeat(jnp.repeat(scale, tm, axis=-2), tn, axis=-1)
    return full[..., :M, :N]


def _amax(x, recipe: str):
    ax = jnp.abs(x.astype(jnp.float32))
    if recipe in ("global", "per_tensor"):
        return jnp.max(ax)
    if recipe == "tile128":
        return _tile_amax(ax)
    raise ValueError(recipe)


def quantize_e4m3(x, recipe: str = "global", stale_scale: bool = False):
    """Returns ``(q, scale)`` with ``x ~= q.astype(f32) * scale`` — ``scale``
    is a scalar for global/per_tensor and the COMPACT per-128-tile array for
    tile128 (``expand_tile_scale`` maps it back to the operand shape)."""
    amax = _amax(x, recipe)
    if stale_scale:
        amax = amax * 0.5          # bug 8: scale from a stale (smaller) amax
    scale = jnp.maximum(amax, 1e-12) / E4M3_MAX
    full = expand_tile_scale(scale, x.shape) if recipe == "tile128" else scale
    q = jnp.clip(x.astype(jnp.float32) / full, -E4M3_MAX, E4M3_MAX)
    return q.astype(F8), scale


def _kernel_tileable(x, w) -> bool:
    return (x.ndim == 2 and w.ndim == 2
            and x.shape[0] % TILE == 0 and x.shape[1] % TILE == 0
            and w.shape[1] % TILE == 0)


def fp8_matmul(x, w, recipe: str = "global", stale_scale: bool = False,
               use_kernel: bool = False):
    """x:(...,K) @ w:(K,N) with fp8 operands, fp32 accumulation."""
    qx, sx = quantize_e4m3(x, recipe, stale_scale=stale_scale)
    qw, sw = quantize_e4m3(w, recipe)
    if recipe == "tile128":
        # per-tile scales cannot be folded outside the contraction (they
        # vary along K); the kernel path applies them per 128-block inside
        # the accumulation loop, the XLA path dequantizes per element.
        if use_kernel and _kernel_tileable(qx, qw):
            from repro.kernels import ops as kops
            return kops.fp8_matmul_tile128(qx, sx, qw, sw)
        xd = qx.astype(jnp.float32) * expand_tile_scale(sx, qx.shape)
        wd = qw.astype(jnp.float32) * expand_tile_scale(sw, qw.shape)
        return jnp.matmul(xd, wd)
    if use_kernel:
        from repro.kernels import ops as kops
        out = kops.fp8_matmul(qx, qw)
    else:
        out = jnp.matmul(qx.astype(jnp.float32), qw.astype(jnp.float32))
    return out * (sx * sw)


def fp8_linear(p, x, recipe="global", stale_scale=False, use_kernel=False):
    """Straight-through-estimator linear: fp8 forward, bf16/fp32 backward
    (the standard TransformerEngine training arrangement)."""
    w = p["w"]

    @jax.custom_vjp
    def f(x, w):
        y = fp8_matmul(x.reshape(-1, x.shape[-1]), w, recipe,
                       stale_scale=stale_scale, use_kernel=use_kernel)
        return y.reshape(*x.shape[:-1], w.shape[-1]).astype(x.dtype)

    def fwd(x, w):
        return f(x, w), (x, w)

    def bwd(res, g):
        x, w = res
        gx = (g @ w.T.astype(g.dtype)).astype(x.dtype)
        gw = jnp.einsum("...i,...o->io", x.astype(jnp.float32),
                        g.astype(jnp.float32)).astype(w.dtype)
        return gx, gw

    f.defvjp(fwd, bwd)
    y = f(x, w)
    if "b" in p:
        y = y + p["b"].astype(y.dtype)
    return y


# ---------------------------------------------------------------------------
# Supervisor-facing candidate factories (the CandidateStep contract)
# ---------------------------------------------------------------------------

def _fp8_loss_call(model, precision: Precision):
    def loss_call(p, b, ctx):
        return model.loss(p, b, ctx=ctx, precision=precision)[0]
    return loss_call


def fp8_precision(recipe: str, bugs=frozenset(),
                  use_kernel: bool = False) -> Precision:
    return Precision(fp8_recipe=recipe,
                     stale_scale="fp8_stale_scale" in bugs,
                     use_kernel=use_kernel)


def make_fp8_runner(model, params, recipe: str, opt=None, opt_state=None,
                    bugs=frozenset(), use_kernel: bool = False):
    """Runner(batch, rewrites) -> Trace: the model with FP8 MLP matmuls."""
    from repro.core.collector import trace_fn_step
    loss_call = _fp8_loss_call(model, fp8_precision(recipe, bugs, use_kernel))

    def run(batch, rewrites=None):
        tr, _, _ = trace_fn_step(loss_call, params, batch, opt=opt,
                                 opt_state=opt_state, rewrites=rewrites)
        return tr

    return run


def make_fp8_train_step(model, ref_params, opt, batch, recipe: str,
                        bugs=frozenset(), use_kernel: bool = False):
    """Once-compiled stateful FP8 candidate train step (supervisor contract).

    Returns ``(step, params0, opt_state0)`` with ``step(params, opt_state,
    batch) -> (Trace, new_params, new_opt_state)`` — the low-precision
    recipe trains under supervision of the full-precision reference with
    BF16-epsilon thresholds (paper §6.7)."""
    from repro.core.collector import make_trace_step
    loss_call = _fp8_loss_call(model, fp8_precision(recipe, bugs, use_kernel))
    step = make_trace_step(loss_call, opt, ref_params, batch)
    params0 = jax.tree.map(jnp.asarray, ref_params)
    return step, params0, opt.init(params0)
