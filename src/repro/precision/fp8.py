"""FP8 (e4m3) training recipes + the stale-scale silent bug (paper §6.7, bug 8).

FP8 matmuls quantize operands to float8_e4m3fn with an amax-derived scale and
accumulate in >= bf16 — so, as the paper observes, the machine epsilon that
governs the *threshold estimation* is still BF16's.  Three scaling recipes
are modelled (paper §6.7):

  * "global":      one scale for the whole tensor (TransformerEngine default)
  * "per_tensor":  alias of global here (per-operand scale)
  * "tile128":     one scale per 128x128 tile (the DeepSeek-V3 recipe) —
                   finer granularity, smaller round-off, as §6.7 predicts.

``fp8_linear`` drops into the reference/parallel MLPs when the precision
recipe asks for it; the Pallas kernel (repro/kernels/fp8_matmul) is the TPU
execution path for the same math.

Bug 8 ("AR: wrong tensor by FP8 cast"): quantization uses a STALE amax — the
scale of the previous microbatch's tensor — modelled by halving the amax:
values clip, the loss is silently wrong.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

E4M3_MAX = 448.0
F8 = jnp.float8_e4m3fn


def _amax(x, recipe: str):
    ax = jnp.abs(x.astype(jnp.float32))
    if recipe in ("global", "per_tensor"):
        return jnp.max(ax)
    if recipe == "tile128":
        M, N = x.shape[-2], x.shape[-1]
        tm, tn = min(128, M), min(128, N)
        pm, pn = -M % tm, -N % tn
        axp = jnp.pad(ax, [(0, 0)] * (ax.ndim - 2) + [(0, pm), (0, pn)])
        Mp, Np = axp.shape[-2], axp.shape[-1]
        t = axp.reshape(*axp.shape[:-2], Mp // tm, tm, Np // tn, tn)
        tile_max = t.max(axis=(-3, -1))                       # (..., mt, nt)
        full = jnp.repeat(jnp.repeat(tile_max, tm, axis=-2), tn, axis=-1)
        return full[..., :M, :N]
    raise ValueError(recipe)


def quantize_e4m3(x, recipe: str = "global", stale_scale: bool = False):
    """Returns (q, scale) with x ~= q.astype(f32) * scale."""
    amax = _amax(x, recipe)
    if stale_scale:
        amax = amax * 0.5          # bug 8: scale from a stale (smaller) amax
    scale = jnp.maximum(amax, 1e-12) / E4M3_MAX
    q = jnp.clip(x.astype(jnp.float32) / scale, -E4M3_MAX, E4M3_MAX)
    return q.astype(F8), scale


def fp8_matmul(x, w, recipe: str = "global", stale_scale: bool = False,
               use_kernel: bool = False):
    """x:(...,K) @ w:(K,N) with fp8 operands, fp32 accumulation."""
    qx, sx = quantize_e4m3(x, recipe, stale_scale=stale_scale)
    qw, sw = quantize_e4m3(w, recipe)
    if use_kernel:
        from repro.kernels import ops as kops
        out = kops.fp8_matmul(qx, qw)
    else:
        out = jnp.matmul(qx.astype(jnp.float32), qw.astype(jnp.float32))
    if recipe == "tile128":
        # per-tile scales: dequantize operands then matmul would defeat the
        # point on real HW; numerically we fold the scale back per element.
        xd = qx.astype(jnp.float32) * sx
        wd = qw.astype(jnp.float32) * sw
        return jnp.matmul(xd, wd)
    return out * (sx * sw)


def fp8_linear(p, x, recipe="global", stale_scale=False):
    """Straight-through-estimator linear: fp8 forward, bf16/fp32 backward
    (the standard TransformerEngine training arrangement)."""
    w = p["w"]

    @jax.custom_vjp
    def f(x, w):
        y = fp8_matmul(x.reshape(-1, x.shape[-1]), w, recipe,
                       stale_scale=stale_scale)
        return y.reshape(*x.shape[:-1], w.shape[-1]).astype(x.dtype)

    def fwd(x, w):
        return f(x, w), (x, w)

    def bwd(res, g):
        x, w = res
        gx = (g @ w.T.astype(g.dtype)).astype(x.dtype)
        gw = jnp.einsum("...i,...o->io", x.astype(jnp.float32),
                        g.astype(jnp.float32)).astype(w.dtype)
        return gx, gw

    f.defvjp(fwd, bwd)
    y = f(x, w)
    if "b" in p:
        y = y + p["b"].astype(y.dtype)
    return y
