"""Production mesh construction.

A FUNCTION, not a module-level constant, so importing this module never
touches jax device state (the dry-run forces 512 host devices before any jax
import; smoke tests must keep seeing 1 device).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """(16,16)=("data","model") single pod (256 chips) or
    (2,16,16)=("pod","data","model") two pods (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_parallel: int = 1):
    """Small mesh over whatever devices exist (CPU runs, tests)."""
    n = len(jax.devices())
    mp = max(1, min(model_parallel, n))
    return jax.make_mesh((n // mp, mp), ("data", "model"))
