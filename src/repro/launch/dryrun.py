import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry run (deliverable e).

Lowers + compiles every (architecture x input shape) pair against the
production mesh — (16,16)=("data","model") single-pod and
(2,16,16)=("pod","data","model") multi-pod — using ShapeDtypeStruct inputs
(no allocation).  Prints/collects:

  * compiled.memory_analysis()  (fits-in-HBM proof)
  * compiled.cost_analysis()    (FLOPs / bytes for the roofline)
  * collective traffic parsed from the optimized HLO

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch tinyllama-1.1b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --out dryrun_report.json
  PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod
"""
import argparse
import json
import time
import traceback

import jax
import numpy as np

from repro.configs.base import INPUT_SHAPES, get_config, list_configs
from repro.core.collector import flatten_named, unflatten_named
from repro.launch import steps as steps_mod
from repro.launch.hlo import parse_hlo_collectives
from repro.launch.mesh import make_production_mesh
from repro.models.model import Model
from repro.optim.adamw import AdamW
from repro.sharding import rules
from jax.sharding import NamedSharding, PartitionSpec as P


def _named_shardings(tree, mesh, opt_state=False):
    named = flatten_named(tree)
    shardings = rules.param_shardings(
        {k: v.shape for k, v in named.items()}, mesh, opt_state=opt_state)
    return unflatten_named(shardings, tree)


def _batch_shardings(specs: dict, mesh, batch_sharded: bool):
    out = {}
    for k, v in specs.items():
        if k == "pos" or v.ndim == 0:
            out[k] = NamedSharding(mesh, P())
            continue
        bspec = rules.batch_pspec(mesh, v.shape[0])
        entries = [bspec] + [P(None)] * (v.ndim - 1)
        spec = P(*(list(bspec) + [None] * (v.ndim - len(bspec))))
        if not batch_sharded and v.ndim >= 2 and k in ("tokens", "labels",
                                                       "features"):
            dp = rules.dp_axes(mesh)
            n = int(np.prod([mesh.shape[a] for a in dp]))
            if v.shape[1] % n == 0:
                spec = P(None, dp if len(dp) > 1 else dp[0])
        out[k] = NamedSharding(mesh, spec)
    return out


def _cache_shardings(cache_sds, mesh, batch_sharded):
    named = flatten_named(cache_sds)
    out = {}
    for name, leaf in named.items():
        spec = rules.cache_pspec(name, leaf.shape, mesh, batch_sharded,
                                 batch_dim=0 if leaf.ndim <= 2 or
                                 leaf.shape[0] > 4096 else
                                 (1 if leaf.ndim >= 3 and leaf.shape[0] <= 128
                                  else 0))
        # stacked (layer-first) caches: batch is dim 1
        out[name] = NamedSharding(mesh, spec)
    return unflatten_named(out, cache_sds)


def dryrun_pair(arch: str, shape_name: str, multi_pod: bool = False,
                verbose: bool = True) -> dict:
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    ok, reason = cfg.supports_shape(shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "status": "skip",
                "reason": reason}
    mesh = make_production_mesh(multi_pod=multi_pod)
    model = Model(cfg)
    t0 = time.time()

    params_sds = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    p_sh = _named_shardings(params_sds, mesh)
    data_sds = steps_mod.input_specs(cfg, shape)
    dp_total = int(np.prod([mesh.shape[a] for a in rules.dp_axes(mesh)]))
    batch_sharded = shape.global_batch % dp_total == 0
    b_sh = _batch_shardings(data_sds, mesh, batch_sharded)

    with rules.activate(mesh, batch_sharded):
        if shape.kind == "train":
            opt = AdamW(lr=1e-4)
            opt_sds = jax.eval_shape(opt.init, params_sds)
            o_sh = _named_shardings(opt_sds, mesh, opt_state=True)
            n_micro = steps_mod.default_n_micro(cfg, shape, dp_total)
            step = steps_mod.make_train_step(Model(cfg), opt,
                                             n_micro=n_micro)
            jitted = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh),
                             out_shardings=(p_sh, o_sh, None))
            lowered = jitted.lower(params_sds, opt_sds, data_sds)
        elif shape.kind == "prefill":
            step = steps_mod.make_prefill_step(model)
            jitted = jax.jit(step, in_shardings=(p_sh, b_sh))
            lowered = jitted.lower(params_sds, data_sds)
        else:  # decode
            cache_sds = steps_mod.cache_specs(model, shape)
            c_sh = _cache_shardings(cache_sds, mesh, batch_sharded)
            step = steps_mod.make_serve_step(model)
            jitted = jax.jit(step, in_shardings=(p_sh, c_sh, b_sh),
                             out_shardings=(None, c_sh))
            lowered = jitted.lower(params_sds, cache_sds, data_sds)

        compiled = lowered.compile()

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    coll = parse_hlo_collectives(compiled.as_text())
    rec = {
        "arch": arch, "shape": shape_name, "status": "ok",
        "n_micro": (steps_mod.default_n_micro(cfg, shape, dp_total)
                    if shape.kind == "train" else 1),
        "multi_pod": multi_pod,
        "mesh": dict(zip(mesh.axis_names, [int(s) for s in
                                           np.shape(mesh.devices)])),
        "compile_s": round(time.time() - t0, 1),
        "flops": float(cost.get("flops", 0.0)),
        "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
        "per_device": {
            "argument_bytes": int(mem.argument_size_in_bytes),
            "output_bytes": int(mem.output_size_in_bytes),
            "temp_bytes": int(mem.temp_size_in_bytes),
            "peak_bytes": int(mem.argument_size_in_bytes
                              + mem.temp_size_in_bytes),
        },
        "collectives": coll,
    }
    if verbose:
        gb = 1 << 30
        pd = rec["per_device"]
        print(f"[{arch} x {shape_name}{' x multipod' if multi_pod else ''}] "
              f"OK in {rec['compile_s']}s | "
              f"args {pd['argument_bytes']/gb:.2f} GiB + temp "
              f"{pd['temp_bytes']/gb:.2f} GiB per device | "
              f"flops {rec['flops']:.3e} | coll "
              f"{coll['total']['operand_bytes']/gb:.3f} GiB "
              f"({coll['total']['count']} ops)")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None,
                    choices=list(INPUT_SHAPES) + [None])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    pairs = []
    archs = list_configs() if (args.all or args.arch is None) else [args.arch]
    shapes = list(INPUT_SHAPES) if (args.all or args.shape is None) \
        else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    records = []
    failures = 0
    for arch in archs:
        if arch == "gpt-paper" and args.all:
            continue   # paper model exercised via benchmarks, not assigned
        for shape in shapes:
            for mp in meshes:
                try:
                    records.append(dryrun_pair(arch, shape, multi_pod=mp))
                except Exception as e:
                    failures += 1
                    traceback.print_exc()
                    records.append({"arch": arch, "shape": shape,
                                    "multi_pod": mp, "status": "fail",
                                    "error": f"{type(e).__name__}: {e}"})
    n_ok = sum(1 for r in records if r["status"] == "ok")
    n_skip = sum(1 for r in records if r["status"] == "skip")
    print(f"\ndry-run: {n_ok} ok, {n_skip} skipped (documented), "
          f"{failures} FAILED")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(records, f, indent=1)
        print("wrote", args.out)
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
