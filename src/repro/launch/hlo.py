"""HLO text analysis: collective-traffic extraction for the roofline.

``compiled.cost_analysis()`` has FLOPs and HBM bytes but no collective
traffic, so we parse the optimized HLO: build a symbol table of every
instruction's result byte-size, then sum operand sizes for each
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute.
"""
from __future__ import annotations

import re
from collections import defaultdict

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_OPND_RE = re.compile(r"%([\w.\-]+)")


def shape_bytes(type_str: str) -> int:
    """Sum byte sizes of every dtype[shape] group in a type string
    (handles tuples)."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def parse_hlo_collectives(hlo_text: str) -> dict:
    """Returns {op_kind: {"count": n, "operand_bytes": b, "result_bytes": b}}
    plus a "total" entry."""
    sizes: dict[str, int] = {}
    lines = hlo_text.splitlines()
    # pass 1: symbol table of result sizes
    for ln in lines:
        m = _DEF_RE.match(ln)
        if not m:
            continue
        name, rhs = m.groups()
        paren = rhs.find(" ")
        head = rhs.split(" ", 1)[0] if paren > 0 else rhs
        sizes[name] = shape_bytes(head)
    # pass 2: collectives
    out = {k: {"count": 0, "operand_bytes": 0, "result_bytes": 0}
           for k in COLLECTIVES}
    for ln in lines:
        m = _DEF_RE.match(ln)
        if not m:
            continue
        name, rhs = m.groups()
        kind = None
        for k in COLLECTIVES:
            if re.search(rf"\)?\s{k}(-start)?\(", rhs) or \
               rhs.split("(")[0].strip().endswith(k) or \
               f" {k}(" in rhs or f" {k}-start(" in rhs:
                kind = k
                break
        if kind is None:
            continue
        # ignore the matching -done ops (they'd double count)
        if f"{kind}-done" in rhs:
            continue
        ent = out[kind]
        ent["count"] += 1
        head = rhs.split(" ", 1)[0]
        ent["result_bytes"] += shape_bytes(head)
        args = rhs[rhs.find("("):]
        # operands named inside the parens; strip attributes after ')'
        depth, end = 0, len(args)
        for i, ch in enumerate(args):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        opnds = _OPND_RE.findall(args[:end])
        ent["operand_bytes"] += sum(sizes.get(o, 0) for o in opnds)
    total = {"count": sum(v["count"] for v in out.values()),
             "operand_bytes": sum(v["operand_bytes"] for v in out.values()),
             "result_bytes": sum(v["result_bytes"] for v in out.values())}
    out["total"] = total
    return out
