"""Batched serving driver: prefill + decode with KV/state caches.

    PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-7b --reduced \
        --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.base import get_config
from repro.data.synthetic import make_batch
from repro.models.model import Model


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if not cfg.is_decoder:
        raise SystemExit(f"{cfg.name} is encoder-only; no decode step")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))

    B, P = args.batch, args.prompt_len
    total = P + args.gen
    prompt = make_batch(cfg, B, P, seed=args.seed)["tokens"]

    decode = jax.jit(model.decode_step)
    cache = model.init_cache(B, total)

    # prefill by stepping the decode path over the prompt (cache-exact);
    # a fused prefill kernel is a perf concern, not a semantic one
    t0 = time.time()
    logits = None
    for t in range(P):
        logits, cache = decode(params, cache, prompt[:, t:t + 1],
                               jnp.int32(t))
    t_prefill = time.time() - t0

    key = jax.random.PRNGKey(args.seed + 1)
    toks = []
    t0 = time.time()
    last = jnp.argmax(logits[:, 0], -1)[:, None]
    for t in range(P, total):
        if args.temperature > 0:
            key, sub = jax.random.split(key)
            last = jax.random.categorical(
                sub, logits[:, 0] / args.temperature)[:, None]
        logits, cache = decode(params, cache, last.astype(jnp.int32),
                               jnp.int32(t))
        nxt = jnp.argmax(logits[:, 0], -1)[:, None]
        toks.append(last)
        last = nxt
    t_dec = time.time() - t0
    out = jnp.concatenate(toks, axis=1)
    print(f"arch={cfg.name} prefill {P} toks in {t_prefill:.2f}s | "
          f"decoded {args.gen} toks/seq x {B} seqs in {t_dec:.2f}s "
          f"({B*args.gen/max(t_dec,1e-9):.1f} tok/s)")
    print("generated token ids (seq 0):", [int(x) for x in out[0]])
    return out


if __name__ == "__main__":
    main()
