"""Supervised-run driver: online TTrace over a multi-step training run.

    PYTHONPATH=src python -m repro.launch.supervise --arch tinyllama-1.1b \
        --reduced --steps 8 --bug zero_skipped_update

    # recipe-generic: pipeline-parallel / FP8 candidates, same workflow
    PYTHONPATH=src python -m repro.launch.supervise --recipe pp \
        --reduced --steps 8 --bug pp_wrong_stage_division
    PYTHONPATH=src python -m repro.launch.supervise --recipe fp8-tile128 \
        --reduced --steps 8 --bug fp8_stale_scale

    # REAL multi-device 1F1B pipeline: per-stage submeshes, microbatched
    # schedule, per-rank traces merged before checking
    PYTHONPATH=src python -m repro.launch.supervise --recipe pp-1f1b \
        --pp 4 --microbatches 4 --reduced --layers 8 --steps 8 \
        --bug pp_stale_boundary

Runs the single-device reference and the candidate recipe (shard_map
dense/MoE/ZeRO-1, staged pipeline, or FP8 — with any injected registry
bugs) in lockstep, checking every step online through the async pipeline;
on a flag the run is bisected to the first bad step and the bug is
localized.  The paper's §3 workflow (steps 1-5), looped per step.  FP8
recipes are checked under BF16-epsilon thresholds automatically (§6.7);
``--reestimate-every R`` re-runs the fused threshold estimate on the live
batch every R steps and tightens the supervised margins.
"""
from __future__ import annotations

import os

if "XLA_FLAGS" not in os.environ:                       # noqa: E402
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import argparse
import dataclasses
import fnmatch
import sys

RECIPES = ("dense", "moe", "zero1", "pp", "pp-1f1b",
           "fp8-global", "fp8-per_tensor", "fp8-tile128")

# each non-shard_map recipe's OWN injectable feature set: a bug that doesn't
# intersect it would be a silent no-op under that recipe
_RECIPE_FEATURES = {"pp": {"pp"}, "pp-1f1b": {"pp", "1f1b"},
                    "fp8": {"fp8"}}


def build_pcfg(args, requires: set, arch_is_moe: bool = False):
    from repro.parallel.api import ParallelConfig
    bugs = frozenset([args.bug]) if args.bug else frozenset()
    recipe = args.recipe or "dense"
    # a bug whose requirements name a recipe pulls that recipe in (so
    # --bug pp_wrong_stage_division alone drives the pp candidate and
    # --bug pp_stale_boundary the 1F1B engine) — but an EXPLICIT
    # conflicting --recipe is refused, never silently replaced
    for feat, forced, fits in (
            ("1f1b", "pp-1f1b", lambda r: r == "pp-1f1b"),
            ("pp", "pp", lambda r: r.startswith("pp")),
            ("fp8", "fp8-global", lambda r: r.startswith("fp8"))):
        if feat in requires and not fits(recipe):
            if args.recipe is not None:
                raise SystemExit(
                    f"bug {args.bug!r} requires the {forced} recipe but "
                    f"--recipe {args.recipe} was given")
            recipe = forced
    if recipe.startswith(("pp", "fp8")):
        # pp/fp8 recipes: refuse explicit shard_map flags instead of
        # silently dropping them
        ignored = [f for f, on in (("--dp", args.dp is not None),
                                   ("--cp", args.cp is not None),
                                   ("--tp", args.tp is not None),
                                   ("--sp", args.sp),
                                   ("--zero1", args.zero1)) if on]
        if ignored:
            raise SystemExit(f"recipe {recipe!r} cannot combine with "
                             f"shard_map flags — {' '.join(ignored)} "
                             f"cannot apply")
        # ... and only express bugs that require their own feature (the pp
        # candidates consult bugs for the stage division and the 1F1B
        # schedule, fp8 for the cast; a shard_map-side bug would be a
        # silent no-op here)
        own = _RECIPE_FEATURES["fp8" if recipe.startswith("fp8")
                               else recipe]
        if args.bug and not (requires & own):
            raise SystemExit(
                f"bug {args.bug!r} is not implemented by the {recipe!r} "
                f"candidate — it injects into the shard_map path")
    if recipe == "pp":
        if args.pp < 2:
            raise SystemExit("--recipe pp needs --pp >= 2 stages")
        pcfg = ParallelConfig(pp=args.pp, bugs=bugs)
    elif recipe == "pp-1f1b":
        if args.pp < 2:
            raise SystemExit("--recipe pp-1f1b needs --pp >= 2 stages")
        if args.microbatches < 2:
            raise SystemExit("--recipe pp-1f1b needs --microbatches >= 2 "
                             "(one microbatch degenerates to the staged "
                             "schedule)")
        if args.batch % args.microbatches:
            raise SystemExit(f"--batch {args.batch} is not divisible into "
                             f"--microbatches {args.microbatches}")
        pcfg = ParallelConfig(pp=args.pp, pp_schedule="1f1b",
                              microbatches=args.microbatches, bugs=bugs)
    elif recipe.startswith("fp8"):
        pcfg = ParallelConfig(fp8=recipe.split("-", 1)[1], bugs=bugs)
    else:
        cp = args.cp if args.cp is not None else (2 if "cp" in requires
                                                  else 1)
        pcfg = ParallelConfig(
            dp=args.dp if args.dp is not None else 2, cp=cp,
            tp=args.tp if args.tp is not None else 2,
            sp=args.sp or "sp" in requires,
            zero1=args.zero1 or recipe == "zero1" or "zero1" in requires,
            bugs=bugs)
    # a bug the built candidate cannot express would silently "pass":
    # refuse instead of reporting a meaningless clean run ("moe" is an
    # arch-side feature — satisfied by the MODEL, so only exempt it when
    # the arch actually has MoE blocks to inject into)
    features = pcfg.features | ({"moe"} if arch_is_moe else set())
    missing = set(requires) - features
    if missing:
        raise SystemExit(
            f"bug {args.bug!r} requires {sorted(missing)} which recipe "
            f"{recipe!r} (arch {args.arch!r}) cannot express — pick a "
            f"matching --recipe / --arch / flags")
    return recipe, pcfg


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None,
                    help="arch config name (default tinyllama-1.1b, or "
                         "mixtral-8x7b for --recipe moe)")
    ap.add_argument("--recipe", default=None, choices=RECIPES,
                    help="candidate recipe: shard_map dense/moe/zero1, "
                         "staged pipeline, real multi-device 1F1B pipeline "
                         "(pp-1f1b), or an fp8 scaling recipe (default "
                         "dense; a --bug requiring pp/1f1b/fp8 pulls that "
                         "recipe in)")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--layers", type=int, default=None,
                    help="override the arch's layer count (deeper reduced "
                         "models for multi-stage pipelines)")
    ap.add_argument("--steps", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--bug", default=None,
                    help="registry bug id to inject into the candidate")
    ap.add_argument("--dp", type=int, default=None,
                    help="data-parallel size (shard_map recipes; default 2)")
    ap.add_argument("--cp", type=int, default=None,
                    help="context-parallel size (default 1, or 2 when the "
                         "bug requires cp)")
    ap.add_argument("--tp", type=int, default=None,
                    help="tensor-parallel size (default 2)")
    ap.add_argument("--pp", type=int, default=2,
                    help="pipeline stages for --recipe pp / pp-1f1b")
    ap.add_argument("--microbatches", type=int, default=4,
                    help="1F1B microbatches per step (--recipe pp-1f1b; "
                         "--batch must divide into them)")
    ap.add_argument("--sp", action="store_true")
    ap.add_argument("--zero1", action="store_true")
    ap.add_argument("--check-every", type=int, default=1,
                    help="online check every C-th step (0 = checking off: "
                         "the bare lockstep loop)")
    ap.add_argument("--async-window", type=int, default=2,
                    help="in-flight online checks (0 = synchronous)")
    ap.add_argument("--no-overlap", action="store_true",
                    help="lockstep mode: shared ref/cand devices, "
                         "synchronous spill + re-estimation (bit-identical "
                         "results; for A/B timing and determinism checks)")
    ap.add_argument("--reestimate-every", type=int, default=0,
                    help="re-estimate thresholds on the live batch every R "
                         "steps (0 = step-0 estimate + constant widening)")
    ap.add_argument("--ckpt-every", type=int, default=4)
    ap.add_argument("--ring-window", type=int, default=4)
    ap.add_argument("--no-spill", action="store_true")
    ap.add_argument("--work-dir", default=None)
    ap.add_argument("--no-stop-on-flag", action="store_true")
    ap.add_argument("--no-localize", action="store_true")
    ap.add_argument("--no-journal", action="store_true",
                    help="skip the fsync'd supervision journal (no resume)")
    ap.add_argument("--resume", action="store_true",
                    help="resume a killed run from its journal; requires "
                         "--work-dir of the interrupted run")
    ap.add_argument("--fault", default=None,
                    help="loud fault to inject (supervise.faults registry: "
                         "crash, hang_check, nan_step, corrupt_spill, "
                         "truncate_ckpt, dead_spill_writer)")
    ap.add_argument("--fault-step", type=int, default=None,
                    help="step the injected fault fires at")
    ap.add_argument("--watchdog-timeout", type=float, default=60.0,
                    help="seconds before a hung check transfer escalates "
                         "to the sync fallback")
    args = ap.parse_args(argv)

    from repro.supervise.faults import make_injector
    try:
        # refusal path: unknown fault, missing/negative step — never a
        # silently ignored malformed spec
        fault = make_injector(args.fault, args.fault_step)
    except ValueError as e:
        raise SystemExit(str(e))
    if args.resume and not args.work_dir:
        raise SystemExit("--resume needs --work-dir (the journal and "
                         "checkpoints of the interrupted run)")

    import jax
    from repro.bugs.registry import BUGS
    from repro.configs.base import get_config
    from repro.models.model import Model
    from repro.optim.adamw import AdamW
    from repro.supervise import Supervisor, SuperviseConfig

    spec = BUGS[args.bug] if args.bug else None
    if args.arch is None:
        args.arch = ("mixtral-8x7b" if args.recipe == "moe"
                     else "tinyllama-1.1b")
    cfg = get_config(args.arch)
    if args.recipe == "moe" and cfg.arch_type != "moe":
        # an explicit non-MoE --arch is refused, never silently replaced
        raise SystemExit(f"--recipe moe needs an MoE arch "
                         f"(e.g. mixtral-8x7b); got --arch {args.arch} "
                         f"[{cfg.arch_type}]")
    if args.reduced:
        cfg = cfg.reduced()
    if args.layers is not None:
        cfg = dataclasses.replace(cfg, n_layers=args.layers)
    # the candidate recipes implement the GPT/Llama/MoE families
    cfg = dataclasses.replace(cfg, tie_embeddings=True)
    recipe, pcfg = build_pcfg(args, set(spec.requires) if spec else set(),
                              arch_is_moe=cfg.arch_type == "moe")

    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    opt = AdamW(lr=args.lr)
    scfg = SuperviseConfig(
        steps=args.steps, check_every=args.check_every,
        async_window=args.async_window, ckpt_every=args.ckpt_every,
        reestimate_every=args.reestimate_every,
        ring_window=args.ring_window, spill=not args.no_spill,
        overlap=not args.no_overlap,
        localize=not args.no_localize,
        stop_on_flag=not args.no_stop_on_flag,
        work_dir=args.work_dir, seed=args.seed,
        journal=not args.no_journal,
        watchdog_timeout_s=args.watchdog_timeout)

    print(f"supervising {cfg.name} ({'reduced' if args.reduced else 'full'}) "
          f"over {args.steps} steps: recipe={recipe} dp={pcfg.dp} "
          f"cp={pcfg.cp} tp={pcfg.tp} pp={pcfg.pp} "
          f"({pcfg.pp_schedule}, microbatches={pcfg.microbatches}) "
          f"sp={pcfg.sp} zero1={pcfg.zero1} fp8={pcfg.fp8} "
          f"async_window={args.async_window} check_every={args.check_every} "
          f"reestimate_every={args.reestimate_every}")
    if spec:
        print(f"injected: {spec.bug_id} [{spec.btype}] — {spec.description}")
    if fault is not None:
        print(f"fault armed: {fault.spec.fault_id} at step {fault.step} — "
              f"{fault.spec.description}")

    sup = Supervisor(model, cfg, pcfg, opt, params=params, scfg=scfg,
                     batch_size=args.batch, seq_len=args.seq, log_fn=print,
                     fault=fault)
    res = sup.resume() if args.resume else sup.run()
    print()
    print(res.summary())
    print(f"  recipe={sup.candidate.name} eps={sup.eps:.2e}, "
          f"checked {len(res.checks)} steps, "
          f"{res.timings.get('steps_per_s', 0):.2f} supervised steps/s "
          f"(pipeline peak in-flight {sup.pipe.max_in_flight}, "
          f"ring: {len(sup.ring.in_memory)} in mem / "
          f"{len(sup.ring.on_disk)} spilled, pinned {sorted(sup.ring.pinned)})")
    if spec and res.flagged:
        loc = res.localized_module or "-"
        # "loss" marks bugs with no module to blame (loss-scaling family);
        # everything else — including "optimizer" — must actually match
        ok = (fnmatch.fnmatchcase(loc, spec.expected_module)
              or spec.expected_module == "loss")
        print(f"  expected module: {spec.expected_module}  ->  "
              f"localized: {loc}  [{'MATCH' if ok else 'MISMATCH'}]")
    return res


if __name__ == "__main__":
    result = main()
    # exit nonzero when the verdict contradicts the injection: a clean run
    # that flags, or an injected bug that goes undetected
    injected = any("--bug" in a for a in sys.argv[1:])
    sys.exit(1 if result.flagged != injected else 0)
