"""Supervised-run driver: online TTrace over a multi-step training run.

    PYTHONPATH=src python -m repro.launch.supervise --arch tinyllama-1.1b \
        --reduced --steps 8 --bug zero_skipped_update

Runs the single-device reference and the distributed candidate (with any
injected registry bugs) in lockstep, checking every step online through the
async pipeline; on a flag the run is bisected to the first bad step and the
bug is localized.  The paper's §3 workflow (steps 1-5), looped per step.
"""
from __future__ import annotations

import os

if "XLA_FLAGS" not in os.environ:                       # noqa: E402
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import argparse
import dataclasses
import fnmatch
import sys


def build_pcfg(args, requires: set):
    from repro.parallel.api import ParallelConfig
    return ParallelConfig(
        dp=args.dp, cp=args.cp if args.cp > 1 else (2 if "cp" in requires
                                                    else 1),
        tp=args.tp, sp=args.sp or "sp" in requires,
        zero1=args.zero1 or "zero1" in requires,
        bugs=frozenset([args.bug]) if args.bug else frozenset())


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--bug", default=None,
                    help="registry bug id to inject into the candidate")
    ap.add_argument("--dp", type=int, default=2)
    ap.add_argument("--cp", type=int, default=1)
    ap.add_argument("--tp", type=int, default=2)
    ap.add_argument("--sp", action="store_true")
    ap.add_argument("--zero1", action="store_true")
    ap.add_argument("--check-every", type=int, default=1)
    ap.add_argument("--async-window", type=int, default=2,
                    help="in-flight online checks (0 = synchronous)")
    ap.add_argument("--ckpt-every", type=int, default=4)
    ap.add_argument("--ring-window", type=int, default=4)
    ap.add_argument("--no-spill", action="store_true")
    ap.add_argument("--work-dir", default=None)
    ap.add_argument("--no-stop-on-flag", action="store_true")
    ap.add_argument("--no-localize", action="store_true")
    args = ap.parse_args(argv)

    import jax
    from repro.bugs.registry import BUGS
    from repro.configs.base import get_config
    from repro.models.model import Model
    from repro.optim.adamw import AdamW
    from repro.supervise import Supervisor, SuperviseConfig

    spec = BUGS[args.bug] if args.bug else None
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    # the distributed candidate implements the GPT/Llama/MoE families
    cfg = dataclasses.replace(cfg, tie_embeddings=True)
    pcfg = build_pcfg(args, set(spec.requires) if spec else set())

    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    opt = AdamW(lr=args.lr)
    scfg = SuperviseConfig(
        steps=args.steps, check_every=args.check_every,
        async_window=args.async_window, ckpt_every=args.ckpt_every,
        ring_window=args.ring_window, spill=not args.no_spill,
        localize=not args.no_localize,
        stop_on_flag=not args.no_stop_on_flag,
        work_dir=args.work_dir, seed=args.seed)

    print(f"supervising {cfg.name} ({'reduced' if args.reduced else 'full'}) "
          f"over {args.steps} steps: dp={pcfg.dp} cp={pcfg.cp} tp={pcfg.tp} "
          f"sp={pcfg.sp} zero1={pcfg.zero1} "
          f"async_window={args.async_window} check_every={args.check_every}")
    if spec:
        print(f"injected: {spec.bug_id} [{spec.btype}] — {spec.description}")

    sup = Supervisor(model, cfg, pcfg, opt, params=params, scfg=scfg,
                     batch_size=args.batch, seq_len=args.seq, log_fn=print)
    res = sup.run()
    print()
    print(res.summary())
    print(f"  checked {len(res.checks)} steps, "
          f"{res.timings.get('steps_per_s', 0):.2f} supervised steps/s "
          f"(pipeline peak in-flight {sup.pipe.max_in_flight}, "
          f"ring: {len(sup.ring.in_memory)} in mem / "
          f"{len(sup.ring.on_disk)} spilled, pinned {sorted(sup.ring.pinned)})")
    if spec and res.flagged:
        loc = res.localized_module or "-"
        # "loss" marks bugs with no module to blame (loss-scaling family);
        # everything else — including "optimizer" — must actually match
        ok = (fnmatch.fnmatchcase(loc, spec.expected_module)
              or spec.expected_module == "loss")
        print(f"  expected module: {spec.expected_module}  ->  "
              f"localized: {loc}  [{'MATCH' if ok else 'MISMATCH'}]")
    return res


if __name__ == "__main__":
    result = main()
    # exit nonzero when the verdict contradicts the injection: a clean run
    # that flags, or an injected bug that goes undetected
    injected = any("--bug" in a for a in sys.argv[1:])
    sys.exit(1 if result.flagged != injected else 0)
