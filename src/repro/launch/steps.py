"""Step functions + input specs for training / prefill / decode.

``input_specs`` returns jax.ShapeDtypeStruct stand-ins for every model input
of an (arch x input-shape) pair — weak-type-correct, shardable, and never
allocated; the dry-run lowers against them.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, InputShape
from repro.models.model import Model
from repro.optim.adamw import AdamW


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype))


def input_specs(cfg: ArchConfig, shape: InputShape) -> dict:
    """ShapeDtypeStructs for the step function's data inputs."""
    B, S = shape.global_batch, shape.seq_len
    if shape.kind in ("train", "prefill"):
        if cfg.arch_type == "audio":
            return {"features": sds((B, S, cfg.audio_dim), "float32"),
                    "mask": sds((B, S), "bool"),
                    "labels": sds((B, S), "int32")}
        if cfg.arch_type == "vlm":
            n_img = min(cfg.n_image_tokens, S - 16)
            return {"tokens": sds((B, S - n_img), "int32"),
                    "labels": sds((B, S - n_img), "int32"),
                    "image_embeds": sds((B, n_img, cfg.vision_dim),
                                        "float32")}
        return {"tokens": sds((B, S), "int32"),
                "labels": sds((B, S), "int32")}
    # decode: one new token against a seq_len cache
    return {"tokens": sds((B, 1), "int32"),
            "pos": sds((), "int32")}


def cache_specs(model: Model, shape: InputShape) -> dict:
    """ShapeDtypeStructs for the decode cache (eval_shape — no allocation)."""
    return jax.eval_shape(
        lambda: model.init_cache(shape.global_batch, shape.seq_len))


def make_train_step(model: Model, opt: AdamW, n_micro: int = 1):
    """Training step with gradient accumulation over ``n_micro`` microbatches
    (scan): per-layer activation saves scale with the microbatch, grads
    accumulate in fp32 sharded like the optimizer state."""
    def train_step(params, opt_state, batch):
        def loss_fn(p, mb):
            loss, metrics = model.loss(p, mb)
            return loss, metrics

        if n_micro == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
        else:
            mbs = jax.tree.map(
                lambda x: x.reshape((n_micro, x.shape[0] // n_micro)
                                    + x.shape[1:]), batch)

            def micro(gsum, mb):
                (loss, metrics), g = jax.value_and_grad(
                    loss_fn, has_aux=True)(params, mb)
                gsum = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), gsum, g)
                # keep the accumulator ZeRO-sharded inside the loop too —
                # otherwise the carry adopts the (model-only) grad sharding
                gsum = _constrain_opt_like(gsum)
                return gsum, (loss, metrics)

            gzero = jax.tree.map(
                lambda x: jnp.zeros(x.shape, jnp.float32), params)
            gzero = _constrain_opt_like(gzero)
            gsum, (losses, metricses) = jax.lax.scan(micro, gzero, mbs)
            grads = jax.tree.map(lambda g: g / n_micro, gsum)
            loss = jnp.mean(losses)
            metrics = jax.tree.map(jnp.mean, metricses)

        params, opt_state, info = opt.update(params, grads, opt_state)
        return params, opt_state, {"loss": loss,
                                   "grad_norm": info.grad_norm,
                                   "lr": info.lr, **metrics}
    return train_step


def _constrain_opt_like(tree):
    """ZeRO-style sharding constraint for the fp32 gradient accumulator:
    like the params PLUS the data axes (an unconstrained fp32 accumulator
    sharded over "model" only costs e.g. 27 GiB/device for qwen1.5-110b —
    EXPERIMENTS.md §Perf)."""
    from repro.core.collector import flatten_named, unflatten_named
    from repro.sharding import rules
    ctx = rules.current()
    if ctx is None:
        return tree
    named = flatten_named(tree)
    sh = rules.param_shardings({k: v.shape for k, v in named.items()},
                               ctx.mesh, opt_state=True)
    out = {k: jax.lax.with_sharding_constraint(v, sh[k])
           for k, v in named.items()}
    return unflatten_named(out, tree)


def default_n_micro(cfg: ArchConfig, shape: InputShape, dp_total: int,
                    act_budget_bytes: int = 5 << 30) -> int:
    """Pick a microbatch count so per-device layer-boundary saves
    (L * S * d_model * 2B * B_micro_local) fit the activation budget."""
    import numpy as np
    if shape.kind != "train":
        return 1
    b_local = max(1, shape.global_batch // dp_total)
    per_seq = cfg.n_layers * shape.seq_len * cfg.d_model * 2
    want = max(1, int(np.ceil(b_local * per_seq / act_budget_bytes)))
    while b_local % want:
        want += 1
    return min(want, b_local)


def make_prefill_step(model: Model):
    def prefill_step(params, batch):
        h, aux = model.forward(params, batch)
        # return last-position logits (the serving prefill contract) so the
        # full (B,S,V) logits tensor never materializes
        logits = model.unembed(params, h[:, -1:])
        return logits
    return prefill_step


def make_serve_step(model: Model):
    def serve_step(params, cache, batch):
        logits, cache = model.decode_step(params, cache, batch["tokens"],
                                          batch["pos"])
        return logits, cache
    return serve_step
