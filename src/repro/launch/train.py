"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
        --reduced --steps 50 --batch 8 --seq 128 --ttrace-every 0

Runs the real substrate end-to-end on whatever devices exist: deterministic
data pipeline -> model -> AdamW(fp32 masters) -> checkpointing, with an
optional TTrace verification pass (--ttrace-every N runs the paper's 1-
iteration differential check against a re-jitted candidate every N steps —
the "integrated into the testing pipeline" regression mode of §8).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.store import load_checkpoint, save_checkpoint
from repro.configs.base import get_config
from repro.data.synthetic import make_batch
from repro.launch.steps import make_train_step
from repro.models.model import Model
from repro.optim.adamw import AdamW, warmup_cosine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--reduced", action="store_true",
                    help="train the reduced (CPU-scale) variant")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--n-micro", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--save", default=None, help="checkpoint dir")
    ap.add_argument("--resume", default=None)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--ttrace-every", type=int, default=0,
                    help="run a TTrace differential check every N steps")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = Model(cfg)
    opt = AdamW(lr=warmup_cosine(args.lr, args.steps // 10, args.steps))

    params = model.init(jax.random.PRNGKey(args.seed))
    opt_state = opt.init(params)
    start_step = 0
    if args.resume:
        (params, opt_state), start_step, _ = load_checkpoint(
            args.resume, (params, opt_state))
        print(f"resumed from {args.resume} at step {start_step}")

    n_params = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))
    print(f"arch={cfg.name} ({'reduced' if args.reduced else 'full'}) "
          f"params={n_params/1e6:.1f}M devices={len(jax.devices())}")

    step_fn = jax.jit(make_train_step(model, opt, n_micro=args.n_micro))

    losses = []
    t0 = time.time()
    for step in range(start_step, args.steps):
        batch = make_batch(cfg, args.batch, args.seq, seed=args.seed,
                           step=step)
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        losses.append(float(metrics["loss"]))
        if step % args.log_every == 0 or step == args.steps - 1:
            dt = time.time() - t0
            print(f"step {step:5d} loss {losses[-1]:.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"lr {float(metrics['lr']):.2e} ({dt:.1f}s)")
        if args.ttrace_every and step and step % args.ttrace_every == 0:
            from repro.core.harness import make_model_runner, ttrace_check
            ref = make_model_runner(model, params, opt, opt_state)
            cand = make_model_runner(model, params, opt, opt_state)
            res = ttrace_check(ref, cand, batch, localize=False)
            print(f"  [ttrace] regression check: "
                  f"{'PASS' if res.passed else 'FAIL'}")
    if args.save:
        save_checkpoint(args.save, (params, opt_state), step=args.steps)
        print("saved to", args.save)
    print(f"final loss {losses[-1]:.4f} (start {losses[0]:.4f})")
    return losses


if __name__ == "__main__":
    main()
