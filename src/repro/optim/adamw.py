"""AdamW with fp32 master weights and main gradients.

Mirrors Megatron's mixed-precision distributed optimizer semantics, which is
what TTrace instruments (paper §4.3):

* model params may be bf16; the optimizer holds an **fp32 master copy**;
* incoming grads are upcast and accumulated in fp32 — the **main gradients**
  TTrace traces right before the step;
* the update runs entirely in fp32 and the model params are re-cast from the
  masters — the **post-step parameters** TTrace traces right after the step.

``update`` returns an ``OptInfo`` carrying both trace bundles so the TTrace
collector never has to reach into optimizer internals.
ZeRO-1 sharding of this state lives in repro/parallel/zero.py.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp


def _reshard_like_opt_state(grads):
    """Under an active GSPMD sharding context, reshard incoming grads to the
    (ZeRO-style data-densified) optimizer-state layout BEFORE the fp32
    upcast — otherwise the fp32 main grads materialize at the params'
    model-only sharding (e.g. 27 GiB/device for qwen1.5-110b; §Perf)."""
    from repro.sharding import rules
    ctx = rules.current()
    if ctx is None:
        return grads
    from repro.core.collector import flatten_named, unflatten_named
    named = flatten_named(grads)
    sh = rules.param_shardings({k: v.shape for k, v in named.items()},
                               ctx.mesh, opt_state=True)
    return unflatten_named(
        {k: jax.lax.with_sharding_constraint(v, sh[k])
         for k, v in named.items()}, grads)


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def warmup_cosine(base_lr: float, warmup: int, total: int,
                  min_ratio: float = 0.1) -> Callable:
    def lr(step):
        step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
        w = jnp.minimum(1.0, (step + 1) / max(warmup, 1))
        prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return base_lr * w * cos
    return lr


@jax.tree_util.register_dataclass
@dataclass
class OptInfo:
    loss_scale: jax.Array
    grad_norm: jax.Array
    lr: jax.Array
    main_grads: Any      # fp32 grads after clipping — TTrace "main gradients"
    pre_clip_norm: jax.Array


@dataclass
class AdamW:
    lr: float | Callable = 1e-3
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    clip: float = 1.0
    # parameters whose path matches any of these suffixes skip weight decay
    no_decay_suffixes: tuple = ("norm", "b", "bias", "mu", "u", "w0", "D",
                                "A_log", "dt_bias", "mu_x", "mu_k", "mu_r")

    def init(self, params):
        f32 = lambda t: jax.tree.map(
            lambda x: jnp.zeros(x.shape, jnp.float32), t)
        master = jax.tree.map(lambda x: x.astype(jnp.float32), params)
        return {"master": master, "m": f32(params), "v": f32(params),
                "step": jnp.zeros((), jnp.int32)}

    def _decay_mask(self, params):
        paths = jax.tree_util.tree_flatten_with_path(params)[0]

        def leaf_decay(path):
            last = str(path[-1].key if hasattr(path[-1], "key") else path[-1])
            return not any(last == s or last.endswith("_norm") or
                           last.startswith("mu") or last in ("b",)
                           for s in self.no_decay_suffixes)
        flat = [leaf_decay(p) for p, _ in paths]
        return jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(params), flat)

    def update(self, params, grads, state, loss_scale=None):
        step = state["step"] + 1
        lr = self.lr(state["step"]) if callable(self.lr) else jnp.float32(self.lr)
        grads = _reshard_like_opt_state(grads)
        main_grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        if loss_scale is not None:
            main_grads = jax.tree.map(lambda g: g / loss_scale, main_grads)
        pre_norm = global_norm(main_grads)
        if self.clip:
            scale = jnp.minimum(1.0, self.clip / jnp.maximum(pre_norm, 1e-12))
            main_grads = jax.tree.map(lambda g: g * scale, main_grads)
        gnorm = global_norm(main_grads)

        decay = self._decay_mask(params)
        b1, b2 = self.b1, self.b2
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(master, g, m, v, dec):
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            u = (m / bc1) / (jnp.sqrt(v / bc2) + self.eps)
            if self.weight_decay:
                u = u + jnp.where(dec, self.weight_decay, 0.0) * master
            return master - lr * u, m, v

        new = jax.tree.map(upd, state["master"], main_grads, state["m"],
                           state["v"], decay)
        master = jax.tree.map(lambda t: t[0], new, is_leaf=lambda t: isinstance(t, tuple))
        m = jax.tree.map(lambda t: t[1], new, is_leaf=lambda t: isinstance(t, tuple))
        v = jax.tree.map(lambda t: t[2], new, is_leaf=lambda t: isinstance(t, tuple))
        new_params = jax.tree.map(lambda mast, p: mast.astype(p.dtype),
                                  master, params)
        info = OptInfo(loss_scale=jnp.float32(loss_scale or 1.0),
                       grad_norm=gnorm, lr=jnp.float32(lr),
                       main_grads=main_grads, pre_clip_norm=pre_norm)
        return new_params, {"master": master, "m": m, "v": v, "step": step}, info
