"""Worker: detection latency — naive loss-curve watching vs TTrace (§6.4).

The naive practice trains BOTH the single-device reference and the
distributed candidate, watching for a >=3% smoothed-loss gap.  TTrace runs
ONE instrumented iteration.  The injected bug is dp_wrong_loss_scale — the
grads are 2x but gradient clipping mostly hides it, so the curves stay close
for a long time (the paper's Fig 1 blindness).

Prints TSV: metric \t value
"""
import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

import dataclasses
import time

import jax
import numpy as np

from repro.configs.base import get_config
from repro.core.harness import make_model_runner, ttrace_check
from repro.data.synthetic import make_batch
from repro.launch.steps import make_train_step
from repro.models.model import Model
from repro.optim.adamw import AdamW
from repro.parallel.api import (ParallelConfig, make_candidate_runner,
                                make_plain_train_step)

BUG = "dp_wrong_loss_scale"
MAX_STEPS = 300
GAP = 0.03


def main():
    cfg = dataclasses.replace(get_config("gpt-paper").reduced(),
                              n_layers=2, vocab=512, tie_embeddings=True)
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    opt = AdamW(lr=3e-3)
    pc = ParallelConfig(dp=2, tp=2, bugs=frozenset([BUG]))

    # --- naive: train both, watch the loss ---------------------------------
    t0 = time.time()
    ref_step = jax.jit(make_train_step(m, opt))
    rp, rs = params, opt.init(params)
    cstep, prep, cp_, cs_ = make_plain_train_step(cfg, pc, params, opt)
    ref_hist, cand_hist = [], []
    detect_step = None
    for step in range(MAX_STEPS):
        batch = make_batch(cfg, 4, 32, step=step)
        rp, rs, met = ref_step(rp, rs, batch)
        ref_hist.append(float(met["loss"]))
        cp_, cs_, closs = cstep(cp_, cs_, prep(batch))
        cand_hist.append(float(closs))
        if step >= 20:
            r = np.mean(ref_hist[-20:])
            c = np.mean(cand_hist[-20:])
            if abs(c - r) / max(r, 1e-9) > GAP and detect_step is None:
                detect_step = step
                break
    t_naive = time.time() - t0

    # --- ttrace: one instrumented iteration --------------------------------
    t0 = time.time()
    ref = make_model_runner(m, params, opt, opt.init(params))
    cand = make_candidate_runner(cfg, pc, params, opt, opt.init(params))
    res = ttrace_check(ref, cand, make_batch(cfg, 4, 32), localize=True)
    t_ttrace = time.time() - t0

    print(f"naive_detect_step\t{detect_step if detect_step is not None else f'>{MAX_STEPS}'}")
    print(f"naive_seconds\t{t_naive:.1f}")
    print(f"ttrace_detected\t{not res.passed}")
    print(f"ttrace_localized\t{res.localized_module}")
    print(f"ttrace_seconds\t{t_ttrace:.1f}")
    print(f"speedup\t{t_naive / max(t_ttrace, 1e-9):.1f}")
    print(f"loss_gap_final\t{abs(np.mean(cand_hist[-20:]) - np.mean(ref_hist[-20:])) / np.mean(ref_hist[-20:]):.4f}")


if __name__ == "__main__":
    main()
