"""Worker (runs under 8 forced host devices): the paper's Table 1 sweep.

For every injectable silent bug: run TTrace on a clean candidate (must PASS)
and on the bug-injected candidate (must FAIL + localize).  Prints one TSV row
per bug:  bug_id  type  clean_pass  detected  localized  expected  loc_ok  secs
"""
import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

import dataclasses
import fnmatch
import sys
import time

import jax

from repro.bugs.registry import BUGS
from repro.configs.base import MoEConfig, get_config
from repro.core.harness import make_model_runner, ttrace_check
from repro.data.synthetic import make_batch
from repro.models.model import Model
from repro.optim.adamw import AdamW
from repro.parallel.api import ParallelConfig, make_candidate_runner


def pcfg_for(spec, bug_on=True):
    req = set(spec.requires)
    return ParallelConfig(
        dp=2, cp=2 if "cp" in req else 1, tp=2,
        sp=("sp" in req), zero1=("zero1" in req),
        bugs=frozenset([spec.bug_id]) if bug_on else frozenset())


def main():
    only = sys.argv[1] if len(sys.argv) > 1 else None
    base = dataclasses.replace(get_config("gpt-paper").reduced(),
                               n_layers=2, vocab=512, tie_embeddings=True)
    moe_cfg = dataclasses.replace(
        base, arch_type="moe", tie_embeddings=False,
        moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=128,
                      capacity_factor=0.0))
    for bid, spec in BUGS.items():
        if only and bid != only:
            continue
        if "pp" in spec.requires or "fp8" in spec.requires:
            continue   # exercised by dedicated benchmarks/tests
        t0 = time.time()
        cfg = moe_cfg if "moe" in spec.requires else base
        m = Model(cfg)
        params = m.init(jax.random.PRNGKey(0))
        opt = AdamW(lr=1e-3)
        st = opt.init(params)
        batch = make_batch(cfg, 4, 32)
        ref = make_model_runner(m, params, opt, st)
        clean = make_candidate_runner(cfg, pcfg_for(spec, False), params,
                                      opt, st)
        buggy = make_candidate_runner(cfg, pcfg_for(spec, True), params,
                                      opt, st)
        r_clean = ttrace_check(ref, clean, batch, localize=False)
        r_buggy = ttrace_check(ref, buggy, batch, localize=True)
        loc = r_buggy.localized_module or "-"
        ok_loc = (fnmatch.fnmatchcase(loc, spec.expected_module)
                  or spec.expected_module in ("loss", "optimizer"))
        print("\t".join(map(str, [
            bid, spec.btype, r_clean.passed, not r_buggy.passed, loc,
            spec.expected_module, ok_loc, round(time.time() - t0, 1)])))
        sys.stdout.flush()


if __name__ == "__main__":
    main()
