"""Roofline worker (512 forced host devices): component-wise lowering.

Lowers each block kind / stem / optimizer unrolled on the production mesh,
reads cost_analysis + collective bytes, composes totals per (arch x shape),
prints one JSON record per line.  See benchmarks.roofline for the method.
"""
import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=512")

import argparse
import dataclasses
import json
import sys
import traceback

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import INPUT_SHAPES, get_config, list_configs
from repro.core.collector import flatten_named, unflatten_named
from repro.launch import steps as steps_mod
from repro.launch.hlo import parse_hlo_collectives
from repro.launch.mesh import make_production_mesh
from repro.models import attention as attn_mod
from repro.models import model as model_mod
from repro.models.model import Model, block_apply, block_init, \
    block_init_cache, build_plan
from repro.optim.adamw import AdamW
from repro.sharding import rules

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9


def cost_cfg(cfg, seq):
    """Variant whose primitives are scan-free (correct flop counting).
    SSM chunked scans keep the production chunk size but run as an unrolled
    python loop (ssm.UNROLL_SCAN)."""
    from repro.models import ssm as ssm_mod
    ssm_mod.UNROLL_SCAN = True
    return dataclasses.replace(cfg, scan_layers=False)


def _cost(lowered):
    c = lowered.compile()
    ca = c.cost_analysis()
    coll = parse_hlo_collectives(c.as_text())
    return {"flops": float(ca.get("flops", 0.0)),
            "bytes": float(ca.get("bytes accessed", 0.0)),
            "coll": float(coll["total"]["operand_bytes"]),
            "coll_ops": int(coll["total"]["count"])}


def _scaled(c, k):
    return {kk: v * k for kk, v in c.items()}


def _add(*cs):
    out = {"flops": 0.0, "bytes": 0.0, "coll": 0.0, "coll_ops": 0.0}
    for c in cs:
        for k in out:
            out[k] += c[k]
    return out


def _shard_params(named_sds, mesh, prefix=""):
    return {n: NamedSharding(mesh, rules.param_pspec(prefix + n, s.shape,
                                                     mesh))
            for n, s in named_sds.items()}


def block_cost(cfg, kind, mesh, B, S, mode, seq_len=None):
    """mode: 'train' | 'fwd' | 'decode'."""
    cfg2 = cost_cfg(cfg, S if mode != "decode" else (seq_len or S))
    psds = jax.eval_shape(
        lambda k: block_init(k, cfg2, kind, jnp.dtype(cfg.param_dtype)),
        jax.random.PRNGKey(0))
    named = flatten_named(psds)
    psh = unflatten_named(_shard_params(named, mesh, "layers.0."), psds)
    bspec = rules.batch_pspec(mesh, B)
    x_sds = jax.ShapeDtypeStruct((B, 1 if mode == "decode" else S,
                                  cfg.d_model), jnp.dtype(cfg.compute_dtype))
    x_sh = NamedSharding(mesh, P(*(list(bspec) + [None, None])))

    if mode == "train":
        def f(p, x):
            def g(p, x):
                out, aux, _ = block_apply(p, cfg2, kind, x, None)
                return (out.astype(jnp.float32) ** 2).sum() * 0.5 + aux
            fn = jax.checkpoint(g) if cfg.remat else g
            return jax.value_and_grad(fn, argnums=(0, 1))(p, x)
        low = jax.jit(f, in_shardings=(psh, x_sh)).lower(psds, x_sds)
    elif mode == "fwd":
        def f(p, x):
            out, aux, _ = block_apply(p, cfg2, kind, x, None)
            return out
        low = jax.jit(f, in_shardings=(psh, x_sh)).lower(psds, x_sds)
    else:
        csds = jax.eval_shape(
            lambda: block_init_cache(cfg2, kind, B, seq_len,
                                     jnp.dtype(cfg.compute_dtype)))
        cnamed = flatten_named(csds)
        csh = unflatten_named(
            {n: NamedSharding(mesh, rules.cache_pspec(
                n, s.shape, mesh, B % 256 == 0, 0))
             for n, s in cnamed.items()}, csds)

        def f(p, c, x):
            out, aux, nc = block_apply(p, cfg2, kind, x, None, cache=c,
                                       pos=jnp.int32(seq_len - 1),
                                       decode=True)
            return out, nc
        low = jax.jit(f, in_shardings=(psh, csh, x_sh)).lower(psds, csds,
                                                              x_sds)
    return _cost(low)


def stem_cost(cfg, mesh, B, S, mode, shape):
    cfg0 = dataclasses.replace(cost_cfg(cfg, S), n_layers=0)
    model0 = Model(cfg0)
    psds = jax.eval_shape(model0.init, jax.random.PRNGKey(0))
    named = flatten_named(psds)
    psh = unflatten_named(_shard_params(named, mesh), psds)
    model_mod.COST_MODE = True
    try:
        if mode == "decode":
            data = {"tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32)}
            bsh = {"tokens": NamedSharding(
                mesh, P(*(list(rules.batch_pspec(mesh, B)) + [None])))}

            def f(p, b):
                h = model0.embed(p, b)
                from repro.models.layers import rmsnorm
                h = rmsnorm(p["final_norm"], h)
                return model0.unembed(p, h)
            low = jax.jit(f, in_shardings=(psh, bsh)).lower(psds, data)
        else:
            data = steps_mod.input_specs(cfg0, shape)
            from repro.launch.dryrun import _batch_shardings
            bsh = _batch_shardings(data, mesh, True)
            if mode == "train":
                def f(p, b):
                    return jax.value_and_grad(
                        lambda pp: model0.loss(pp, b)[0])(p)
            else:
                def f(p, b):
                    h, _ = model0.forward(p, b)
                    return model0.unembed(p, h[:, -1:])
            low = jax.jit(f, in_shardings=(psh, bsh)).lower(psds, data)
        return _cost(low)
    finally:
        model_mod.COST_MODE = False


def opt_cost(cfg, mesh):
    model = Model(cfg)
    psds = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    named = flatten_named(psds)
    psh = unflatten_named(_shard_params(named, mesh), psds)
    opt = AdamW(lr=1e-4)
    osds = jax.eval_shape(opt.init, psds)
    onamed = flatten_named(osds)
    osh = unflatten_named(
        {n: NamedSharding(
            mesh, rules.with_data_axis(
                rules.param_pspec(n.split(".", 1)[-1], s.shape, mesh),
                s.shape, mesh, rules.dp_axes(mesh)))
         for n, s in onamed.items()}, osds)
    low = jax.jit(opt.update, in_shardings=(psh, psh, osh)).lower(
        psds, psds, osds)
    return _cost(low)


def active_params(cfg) -> tuple[int, int]:
    model = Model(cfg)
    psds = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    named = flatten_named(psds)
    total = active = 0
    for n, s in named.items():
        cnt = int(np.prod(s.shape))
        if "word_embeddings" in n or n == "lm_head":
            continue
        total += cnt
        if ".experts." in n and cfg.moe is not None:
            active += cnt * cfg.moe.top_k // cfg.moe.n_experts
        else:
            active += cnt
    return total, active


def roofline_pair(arch, shape_name):
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    ok, reason = cfg.supports_shape(shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "status": "skip",
                "reason": reason}
    mesh = make_production_mesh()
    chips = mesh.size
    plan = build_plan(cfg)
    dp_total = int(np.prod([mesh.shape[a] for a in rules.dp_axes(mesh)]))

    if shape.kind == "train":
        n_micro = steps_mod.default_n_micro(cfg, shape, dp_total)
        B_eff = shape.global_batch // n_micro
        mode = "train"
    else:
        n_micro = 1
        B_eff = shape.global_batch
        mode = "fwd" if shape.kind == "prefill" else "decode"

    kinds = {}
    for seg in plan:
        kinds[seg.kind] = kinds.get(seg.kind, 0) + seg.n
    total = _add()
    parts = {}
    batch_sharded = shape.global_batch % dp_total == 0
    with rules.activate(mesh, batch_sharded):
        for kind, count in kinds.items():
            c = block_cost(cfg, kind, mesh, B_eff, shape.seq_len, mode,
                           seq_len=shape.seq_len)
            parts[f"block:{kind}x{count}"] = c
            total = _add(total, _scaled(c, count))
        stem = stem_cost(cfg, mesh, B_eff, shape.seq_len, mode, shape)
        parts["stem"] = stem
        total = _add(total, stem)
        total = _scaled(total, n_micro)
        if mode == "train":
            oc = opt_cost(cfg, mesh)
            parts["opt"] = oc
            total = _add(total, oc)

    terms = {"compute": total["flops"] / PEAK_FLOPS,
             "memory": total["bytes"] / HBM_BW,
             "collective": total["coll"] / ICI_BW}
    dom = max(terms, key=terms.get)
    n_total, n_active = active_params(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        model_flops = 6 * n_active * tokens
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        model_flops = 2 * n_active * tokens
    else:
        model_flops = 2 * n_active * shape.global_batch
    hlo_flops_global = total["flops"] * chips
    rec = {
        "arch": arch, "shape": shape_name, "status": "ok",
        "chips": chips, "n_micro": n_micro,
        "per_device": total,
        "parts": {k: v for k, v in parts.items()},
        "terms": terms, "dominant": dom,
        "model_flops": model_flops,
        "hlo_flops_global": hlo_flops_global,
        "useful_ratio": (model_flops / hlo_flops_global
                         if hlo_flops_global else 0.0),
        "n_params": n_total, "n_active": n_active,
    }
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--archs", default=None)
    ap.add_argument("--shapes", default=None)
    args = ap.parse_args()
    archs = (args.archs.split(",") if args.archs else
             [a for a in list_configs() if a != "gpt-paper"])
    shapes = args.shapes.split(",") if args.shapes else list(INPUT_SHAPES)
    for arch in archs:
        for shp in shapes:
            try:
                rec = roofline_pair(arch, shp)
            except Exception as e:
                traceback.print_exc()
                rec = {"arch": arch, "shape": shp, "status": "fail",
                       "error": f"{type(e).__name__}: {e}"}
            print(json.dumps(rec))
            sys.stdout.flush()


if __name__ == "__main__":
    main()
