"""Worker: FP round-off threshold curves + bug/FP error separation.

Reproduces (CPU-scaled) paper Fig 7 and Fig 8 on a BF16 mixed-precision GPT:

 * estimated FP round-off error per layer (input perturbed at bf16 epsilon),
   for forward activations, activation gradients and parameter gradients;
 * the actual FP error of a CORRECT tensor-parallel candidate per layer;
 * bug-induced errors for a forward bug (bug 1: wrong embedding mask) and a
   backward bug (bug 11 class: stale wgrad) per layer.

Prints TSV: section  layer  name  value   (values normalized by bf16 eps).
"""
import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

import dataclasses
import sys

import jax
import numpy as np

from repro.configs.base import get_config
from repro.core.harness import make_model_runner
from repro.core.thresholds import (MACHINE_EPS, estimate_thresholds, rel_err)
from repro.data.synthetic import make_batch
from repro.models.model import Model
from repro.optim.adamw import AdamW
from repro.parallel.api import ParallelConfig, make_candidate_runner

EPS = MACHINE_EPS["bfloat16"]


def main():
    L = int(sys.argv[1]) if len(sys.argv) > 1 else 16
    cfg = dataclasses.replace(
        get_config("gpt-paper").reduced(), n_layers=L, d_model=128,
        n_heads=4, n_kv_heads=4, d_ff=256, vocab=512, tie_embeddings=True,
        compute_dtype="bfloat16")
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    opt = AdamW(lr=1e-3)
    st = opt.init(params)
    batch = make_batch(cfg, 2, 64)
    ref = make_model_runner(m, params, opt, st)

    thr, base = estimate_thresholds(ref, batch, EPS)
    pc = ParallelConfig(dp=2, tp=2)
    cand = make_candidate_runner(cfg, pc, params, opt, st)(batch)

    bug_fwd = make_candidate_runner(
        cfg, dataclasses.replace(pc, bugs=frozenset(
            ["tp_wrong_embedding_mask"])), params, opt, st)(batch)
    bug_bwd = make_candidate_runner(
        cfg, dataclasses.replace(
            pc, sp=True, bugs=frozenset(["sp_stale_wgrad"])),
        params, opt, st)(batch)

    def dump(section, getter):
        for li in range(L):
            for role, key in (("attn_out", f"layers.{li}.self_attention/output"),
                              ("mlp_out", f"layers.{li}.mlp/output")):
                v = getter(key)
                if v is not None:
                    print(f"{section}\t{li}\t{role}\t{v / EPS:.4f}")

    dump("est_act", lambda k: thr.per_tensor["activation"].get(k))
    dump("est_agrad", lambda k: thr.per_tensor["act_grad"].get(k))
    dump("dist_act",
         lambda k: rel_err(base.activations[k], cand.activations[k]))
    dump("dist_agrad",
         lambda k: rel_err(base.act_grads[k], cand.act_grads[k]))
    dump("bugfwd_act",
         lambda k: rel_err(base.activations[k], bug_fwd.activations[k]))
    dump("bugbwd_agrad",
         lambda k: rel_err(base.act_grads[k], bug_bwd.act_grads[k]))
    # param-grad estimates per layer (Fig 7c analogue)
    for li in range(L):
        k = f"layers.{li}.self_attention.linear_qkv.w"
        v = thr.per_tensor["param_grad"].get(k)
        if v is not None:
            print(f"est_pgrad\t{li}\tqkv_w\t{v / EPS:.4f}")
        print(f"bugbwd_pgrad\t{li}\tproj_w\t"
              f"{rel_err(base.param_grads[f'layers.{li}.self_attention.linear_proj.w'], bug_bwd.param_grads[f'layers.{li}.self_attention.linear_proj.w']) / EPS:.4f}")


if __name__ == "__main__":
    main()
