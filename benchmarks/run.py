"""Benchmark entrypoint: one function per paper table/figure + the roofline.

Prints ``name,us_per_call,derived`` CSV rows (benchmarks.common.emit).

  table1   -> benchmarks.bug_table          (silent-bug detection sweep)
  fig7+8   -> benchmarks.threshold_curves   (FP thresholds vs depth; bug sep)
  fig9     -> benchmarks.fp8_smoothness     (FP8 recipes stay smooth)
  sec6.4   -> benchmarks.overhead           (detection latency vs naive)
  kernels  -> benchmarks.kernel_bench       (Pallas vs oracle sweep)
  checker  -> benchmarks.checker_bench      (batched vs loop trace checking)
  roofline -> benchmarks.roofline           (3-term analysis; --roofline)

``--json PATH`` additionally writes the emitted rows as machine-readable
JSON (name -> us_per_call) so PRs leave a perf trajectory behind.
"""
from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma list: bug_table,curves,fp8,overhead,kernels,"
                         "checker,supervisor,roofline")
    ap.add_argument("--roofline", action="store_true",
                    help="include the (slow, 512-device) roofline sweep")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write rows as JSON {name: us_per_call}")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny-step smoke mode: exercises every selected "
                         "bench end to end but writes NO BENCH_*.json "
                         "(keeps the tracked rows honest) — the test "
                         "suite's rot guard")
    args = ap.parse_args()
    if args.smoke:
        import os
        os.environ["REPRO_BENCH_SMOKE"] = "1"
    want = set(args.only.split(",")) if args.only else None

    def on(name):
        return want is None or name in want

    print("name,us_per_call,derived")
    failures = []

    if on("kernels"):
        from benchmarks import kernel_bench
        _safe(kernel_bench.run, failures, "kernels")
    if on("checker"):
        from benchmarks import checker_bench
        _safe(checker_bench.run, failures, "checker")
    if on("supervisor"):
        from benchmarks import supervisor_bench
        _safe(supervisor_bench.run, failures, "supervisor")
    if on("fp8"):
        from benchmarks import fp8_smoothness
        _safe(fp8_smoothness.run, failures, "fp8")
    if on("curves"):
        from benchmarks import threshold_curves
        _safe(threshold_curves.run, failures, "curves")
    if on("bug_table"):
        from benchmarks import bug_table
        _safe(bug_table.run, failures, "bug_table")
    if on("overhead"):
        from benchmarks import overhead
        _safe(overhead.run, failures, "overhead")
    if on("roofline") and (args.roofline or (want and "roofline" in want)):
        from benchmarks import roofline
        _safe(roofline.run, failures, "roofline")

    if args.json:
        from benchmarks.common import write_json
        write_json(args.json)

    if failures:
        print(f"# {len(failures)} benchmark(s) failed: {failures}")
        sys.exit(1)
    print("# all benchmarks completed")


def _safe(fn, failures, name):
    try:
        fn()
    except Exception:
        traceback.print_exc()
        failures.append(name)


if __name__ == "__main__":
    main()
