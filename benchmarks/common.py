"""Benchmark harness utilities: timing + the ``name,us_per_call,derived``
CSV contract used by benchmarks.run."""
from __future__ import annotations

import os
import subprocess
import sys
import time

ROWS: list[tuple] = []


def emit(name: str, us_per_call: float, derived: str = ""):
    ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.1f},{derived}")


def write_json(path: str, rows=None):
    """Persist emitted rows as {name: us_per_call} (BENCH_*.json contract).

    A no-op under ``REPRO_BENCH_SMOKE`` (benchmarks.run --smoke): smoke
    runs exercise every bench but must never overwrite tracked rows with
    tiny-step numbers — enforced here so EVERY bench honors it."""
    import json
    if os.environ.get("REPRO_BENCH_SMOKE"):
        return
    with open(path, "w") as f:
        json.dump({name: us for name, us, _ in (rows or ROWS)}, f,
                  indent=2, sort_keys=True)


def timeit(fn, *args, warmup: int = 1, iters: int = 5) -> float:
    """Median wall time per call in microseconds."""
    for _ in range(warmup):
        fn(*args)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn(*args)
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2] * 1e6


def run_worker(module: str, *args, devices: int = 8, timeout: int = 1800
               ) -> str:
    """Run a benchmark worker in a subprocess with N forced host devices
    (the main process must keep seeing 1 device)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = "src"
    out = subprocess.run(
        [sys.executable, "-m", module, *map(str, args)],
        capture_output=True, text=True, timeout=timeout, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    if out.returncode != 0:
        raise RuntimeError(f"{module} failed:\n{out.stdout}\n{out.stderr}")
    return out.stdout
