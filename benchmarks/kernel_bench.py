"""Pallas kernel validation + timing sweep (shapes x dtypes vs oracles)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timeit
from repro.kernels import ops, ref


def run():
    key = jax.random.PRNGKey(0)

    # flash attention: shape/dtype/mode sweep
    for (B, S, H, Hkv, D, mode, w, dt) in [
            (2, 256, 4, 2, 64, "causal", 0, jnp.float32),
            (1, 512, 8, 2, 128, "causal", 0, jnp.bfloat16),
            (1, 256, 4, 4, 64, "swa", 64, jnp.float32),
            (2, 128, 2, 2, 64, "bidirectional", 0, jnp.float32)]:
        ks = jax.random.split(key, 3)
        q = jax.random.normal(ks[0], (B, S, H, D), dt)
        k = jax.random.normal(ks[1], (B, S, Hkv, D), dt)
        v = jax.random.normal(ks[2], (B, S, Hkv, D), dt)
        o = ops.flash_attention(q, k, v, mode=mode, window=w, bq=64, bk=64)
        r = ref.attention_ref(q, k, v, mode=mode, window=w)
        err = float(jnp.max(jnp.abs(o.astype(jnp.float32)
                                    - r.astype(jnp.float32))))
        us = timeit(lambda: jax.block_until_ready(
            ops.flash_attention(q, k, v, mode=mode, window=w, bq=64, bk=64)))
        emit(f"kernel.flash.{mode}.{S}x{H}x{D}.{jnp.dtype(dt).name}", us,
             f"max_err={err:.2e}")

    # gla scan: scalar + vector decay
    B, S, H, dk, dv = 2, 128, 3, 16, 32
    ks = jax.random.split(key, 4)
    q = jax.random.normal(ks[0], (B, S, H, dk))
    k = jax.random.normal(ks[1], (B, S, H, dk))
    v = jax.random.normal(ks[2], (B, S, H, dv))
    for name, lw, excl in [
            ("mamba_scalar", -jax.nn.softplus(
                jax.random.normal(ks[3], (B, S, H, 1))), False),
            ("rwkv_vector", -0.01 * jax.nn.sigmoid(
                jax.random.normal(ks[3], (B, S, H, dk))), True)]:
        y1, s1 = ops.gla_scan(q, k, v, lw, chunk=32, exclusive=excl)
        y2, s2 = ref.gla_scan_ref(q, k, v, lw, exclusive=excl)
        err = float(jnp.max(jnp.abs(y1.astype(jnp.float32) - y2)))
        us = timeit(lambda: jax.block_until_ready(
            ops.gla_scan(q, k, v, lw, chunk=32, exclusive=excl)[0]))
        emit(f"kernel.gla.{name}", us, f"max_err={err:.2e}")

    # fp8 matmul
    x8 = (10 * jax.random.normal(ks[0], (128, 256))).astype(jnp.float8_e4m3fn)
    w8 = (10 * jax.random.normal(ks[1], (256, 192))).astype(jnp.float8_e4m3fn)
    o = ops.fp8_matmul(x8, w8, bm=64, bn=64, bk=64)
    err = float(jnp.max(jnp.abs(o - ref.fp8_matmul_ref(x8, w8))))
    us = timeit(lambda: jax.block_until_ready(
        ops.fp8_matmul(x8, w8, bm=64, bn=64, bk=64)))
    emit("kernel.fp8_matmul.128x256x192", us, f"max_err={err:.2e}")

    # fused rel-err reduction (the checker's hot loop)
    a = np.random.randn(512, 777).astype(np.float32)
    b = a + 1e-4 * np.random.randn(512, 777).astype(np.float32)
    got = ops.rel_err(a, b)
    want = ref.rel_err_ref(a, b)
    us = timeit(lambda: ops.rel_err(a, b))
    emit("kernel.relerr.512x777", us,
         f"got={got:.3e} ref={want:.3e} agree={abs(got-want)/want < 1e-3}")


if __name__ == "__main__":
    run()
