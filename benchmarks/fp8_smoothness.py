"""Paper Fig 9: FP8 smoothness — threshold estimation under FP8 recipes.

A stack of fp8 linear+gelu layers (e4m3 matmul, bf16-magnitude accumulation)
is perturbed at the BF16 epsilon; the induced relative errors per depth are
reported in units of bf16 eps.  The paper's claims checked here:
  * no exponential blow-up with depth (layers stay smooth under fp8);
  * finer-grained scaling (tile128, the DeepSeek-V3 recipe) gives smaller
    round-off than a global scale.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timeit
from repro.core.thresholds import MACHINE_EPS
from repro.precision.fp8 import fp8_matmul

EPS = MACHINE_EPS["bfloat16"]


def _stack(x, ws, recipe, stale=False):
    for w in ws:
        if recipe == "bf16":
            y = (x.astype(jnp.bfloat16) @ w.astype(jnp.bfloat16)
                 ).astype(jnp.float32)
        else:
            y = fp8_matmul(x, w, recipe=recipe, stale_scale=stale)
        x = jax.nn.gelu(y) / jnp.sqrt(jnp.mean(y * y) + 1e-6)  # keep scale
    return x


def run(L=12, d=256):
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, L + 1)
    x = jax.random.normal(ks[0], (64, d), jnp.float32)
    ws = [0.05 * jax.random.normal(k, (d, d), jnp.float32) for k in ks[1:]]
    dx = jax.random.normal(jax.random.PRNGKey(7), x.shape, jnp.float32)
    xp = x + dx * (EPS * jnp.linalg.norm(x) / jnp.linalg.norm(dx))

    results = {}
    for recipe in ("bf16", "global", "tile128"):
        rel = []
        xs, xps = x, xp
        for li in range(L):
            xs = _stack(xs, ws[li:li + 1], recipe)
            xps = _stack(xps, ws[li:li + 1], recipe)
            rel.append(float(jnp.linalg.norm(xps - xs)
                             / jnp.linalg.norm(xs)) / EPS)
        results[recipe] = rel
        emit(f"fp8_smoothness.{recipe}", 0.0,
             f"rel/eps depth1={rel[0]:.2f} depth{L}={rel[-1]:.2f} "
             f"max={max(rel):.2f}")
    # quantization error of the recipes (vs exact fp32 matmul) on data with
    # per-block outliers — the regime the DeepSeek-V3 tile128 recipe targets
    xbig = jax.random.normal(jax.random.PRNGKey(9), (256, d), jnp.float32)
    xo = xbig.at[:2].mul(4096.0)  # outliers push the rest below e4m3 range
    exact = xo @ ws[0]
    for recipe in ("global", "tile128"):
        q = fp8_matmul(xo, ws[0], recipe=recipe)
        # error on the NON-outlier rows: the global scale sacrifices their
        # precision to the outliers; per-tile scales do not (128-row tiles
        # isolate the two outlier rows' tile)
        qerr = float(jnp.linalg.norm((q - exact)[128:])
                     / jnp.linalg.norm(exact[128:]))
        emit(f"fp8_quant_err.{recipe}", 0.0, f"rel_nonoutlier={qerr:.4f}")
    stale = fp8_matmul(xo, ws[0], recipe="global", stale_scale=True)
    emit("fp8_quant_err.stale_scale_bug", 0.0,
         f"rel={float(jnp.linalg.norm(stale - exact) / jnp.linalg.norm(exact)):.4f}")
    # smoothness: no exponential blow-up (max/first bounded)
    ok = all(max(r) < 50 * r[0] for r in results.values())
    emit("fp8_smoothness.bounded", 0.0, f"no_blowup={ok}")
    return results


if __name__ == "__main__":
    run()
