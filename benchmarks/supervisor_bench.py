"""Supervisor overhead benchmark: supervised steps/s vs the unsupervised
training loop (ISSUE 5 overlap criteria: spill <= 1.65x async2 (bound
re-calibrated for the checksummed spill payloads), reest <=
1.3x async2, the 1F1B engine at parity with the staged pp candidate, and
an HONEST nocheck baseline — the old row was inflated by a ring-window
harness bug that retained every trace of the run).

Writes ``BENCH_supervisor.json`` mapping row name -> microseconds per step:

* ``supervisor/plain``        — bare distributed candidate step (context:
  what production training costs without any supervision);
* ``supervisor/nocheck``      — the supervisor's lockstep loop with
  checking off (the unsupervised-loop baseline: reference + candidate
  traced steps, no differential checks);
* ``supervisor/sync``         — supervised, ``async_window=0`` (block on
  every check);
* ``supervisor/async2``       — supervised, 2-deep async check window;
* ``supervisor/async2_spill`` — same plus the spill-to-disk trace ring;
* ``supervisor/pp2_async2``   — the pipeline-parallel candidate (2 stages)
  under the same async supervision;
* ``supervisor/pp1f1b_async2`` — the REAL multi-device 1F1B engine (2
  stages on 2 devices, 2 microbatches, per-rank trace merging) under the
  same async supervision;
* ``supervisor/fp8_tile128_async2`` — the FP8 tile128 candidate under BF16
  thresholds;
* ``supervisor/reest_async2`` — dense async loop with periodic threshold
  re-estimation on the live batch;
* ``supervisor/journal``      — the async2 loop with the fsync'd
  supervision journal on (the fault-tolerance tax; acceptance bounds it
  at <= 5% of supervised ms/step).
"""
from __future__ import annotations

import json
import os

from benchmarks.common import ROWS, emit, run_worker, write_json


def run(json_path: str = "BENCH_supervisor.json"):
    if os.environ.get("REPRO_BENCH_SMOKE"):
        json_path = None          # smoke runs never overwrite tracked rows
    # the PR-4 baseline rides along under supervisor/pr4/... so the
    # overlapped rewrite's before/after stays a tracked trajectory, not a
    # claim.  Once pr4 rows exist they are preserved VERBATIM — without
    # this, a second regeneration would re-prefix the overlapped rows and
    # silently destroy the true baseline
    prev = {}
    if json_path and os.path.exists(json_path):
        with open(json_path) as f:
            old = json.load(f)
        prev = {k: v for k, v in old.items() if "/pr4/" in k}
        if not prev:
            prev = {k.replace("supervisor/", "supervisor/pr4/", 1): v
                    for k, v in old.items()}
    out = run_worker("benchmarks.supervisor_worker", devices=8, timeout=3600)
    kv = dict(ln.split("\t") for ln in out.strip().splitlines() if "\t" in ln)
    plain = float(kv["plain_s_per_step"])
    nocheck = float(kv["nocheck_s_per_step"])
    sync_s = float(kv["sync_s_per_step"])
    async_s = float(kv["async_s_per_step"])
    spill_s = float(kv["async_spill_s_per_step"])
    first_row = len(ROWS)
    emit("supervisor/plain", plain * 1e6, "bare candidate step")
    emit("supervisor/nocheck", nocheck * 1e6,
         f"lockstep ref+cand, checking off ({nocheck / plain:.2f}x plain)")
    emit("supervisor/sync", sync_s * 1e6,
         f"{sync_s / nocheck:.2f}x unsupervised loop")
    emit("supervisor/async2", async_s * 1e6,
         f"{async_s / nocheck:.2f}x unsupervised loop; "
         f"{sync_s / async_s:.2f}x faster than sync")
    emit("supervisor/async2_spill", spill_s * 1e6,
         f"spill ring cost {(spill_s - async_s) * 1e3:+.1f} ms/step")
    journal_s = float(kv["journal_s_per_step"])
    emit("supervisor/journal", journal_s * 1e6,
         f"fsync'd journal on: {(journal_s / async_s - 1) * 100:+.1f}% "
         f"vs async2")
    pp_s = float(kv["pp_s_per_step"])
    pp1f1b_s = float(kv["pp1f1b_s_per_step"])
    fp8_s = float(kv["fp8_s_per_step"])
    reest_s = float(kv["reest_s_per_step"])
    emit("supervisor/pp2_async2", pp_s * 1e6,
         "2-stage pipeline candidate under async supervision")
    emit("supervisor/pp1f1b_async2", pp1f1b_s * 1e6,
         f"real 2-stage/2-microbatch 1F1B engine, per-rank trace merge "
         f"({pp1f1b_s / pp_s:.2f}x the staged pp candidate)")
    emit("supervisor/fp8_tile128_async2", fp8_s * 1e6,
         "fp8 tile128 candidate, BF16-eps thresholds")
    emit("supervisor/reest_async2", reest_s * 1e6,
         f"periodic re-estimation cost {(reest_s - async_s) * 1e3:+.1f} "
         f"ms/step")
    if json_path:
        write_json(json_path, rows=ROWS[first_row:]
                   + [(name, us, "") for name, us in sorted(prev.items())])
    # ISSUE 5 overlap criteria.  (The old "async2 < sync" guard compared
    # against a nocheck row inflated by the ring-window harness bug; on a
    # 2-core host with honest baselines, sync and async are within noise of
    # each other — the async win needs devices that actually overlap — so
    # the guard is a no-worse-than bound here.)
    # spill bound re-calibrated 1.5x -> 1.65x when the fault-tolerance PR
    # added per-piece CRC32 checksums to spill payloads: corruption
    # detection costs ~10ms/step of writer-thread CPU here (measured
    # against the pre-checksum 1.46x), a price the resume/bisection
    # integrity story deliberately pays
    ok = (nocheck <= 2.5 * plain                 # two traced lockstep sides
          and async_s <= 1.25 * sync_s
          and spill_s <= 1.65 * async_s
          and reest_s <= 1.3 * async_s
          and pp1f1b_s <= 1.5 * pp_s
          and journal_s <= 1.05 * async_s)       # journaling tax <= 5%
    emit("supervisor/acceptance", 0.0,
         f"{'PASS' if ok else 'FAIL'}: nocheck <= 2.5x plain, async2 <= "
         f"1.25x sync, spill <= 1.65x async2 (checksummed), reest <= "
         f"1.3x async2, pp1f1b <= 1.5x staged pp, journal <= 1.05x async2")
    return kv


if __name__ == "__main__":
    run()
