"""Supervisor overhead benchmark: supervised steps/s vs the unsupervised
training loop (ISSUE 2 acceptance: async within 2x of unsupervised and
strictly better than check-every-step sync).

Writes ``BENCH_supervisor.json`` mapping row name -> microseconds per step:

* ``supervisor/plain``        — bare distributed candidate step (context:
  what production training costs without any supervision);
* ``supervisor/nocheck``      — the supervisor's lockstep loop with
  checking off (the unsupervised-loop baseline: reference + candidate
  traced steps, no differential checks);
* ``supervisor/sync``         — supervised, ``async_window=0`` (block on
  every check);
* ``supervisor/async2``       — supervised, 2-deep async check window;
* ``supervisor/async2_spill`` — same plus the spill-to-disk trace ring;
* ``supervisor/pp2_async2``   — the pipeline-parallel candidate (2 stages)
  under the same async supervision;
* ``supervisor/pp1f1b_async2`` — the REAL multi-device 1F1B engine (2
  stages on 2 devices, 2 microbatches, per-rank trace merging) under the
  same async supervision;
* ``supervisor/fp8_tile128_async2`` — the FP8 tile128 candidate under BF16
  thresholds;
* ``supervisor/reest_async2`` — dense async loop with periodic threshold
  re-estimation on the live batch.
"""
from __future__ import annotations

from benchmarks.common import ROWS, emit, run_worker, write_json


def run(json_path: str = "BENCH_supervisor.json"):
    out = run_worker("benchmarks.supervisor_worker", devices=8, timeout=3600)
    kv = dict(ln.split("\t") for ln in out.strip().splitlines() if "\t" in ln)
    plain = float(kv["plain_s_per_step"])
    nocheck = float(kv["nocheck_s_per_step"])
    sync_s = float(kv["sync_s_per_step"])
    async_s = float(kv["async_s_per_step"])
    spill_s = float(kv["async_spill_s_per_step"])
    first_row = len(ROWS)
    emit("supervisor/plain", plain * 1e6, "bare candidate step")
    emit("supervisor/nocheck", nocheck * 1e6,
         f"lockstep ref+cand, checking off ({nocheck / plain:.2f}x plain)")
    emit("supervisor/sync", sync_s * 1e6,
         f"{sync_s / nocheck:.2f}x unsupervised loop")
    emit("supervisor/async2", async_s * 1e6,
         f"{async_s / nocheck:.2f}x unsupervised loop; "
         f"{sync_s / async_s:.2f}x faster than sync")
    emit("supervisor/async2_spill", spill_s * 1e6,
         f"spill ring cost {(spill_s - async_s) * 1e3:+.1f} ms/step")
    pp_s = float(kv["pp_s_per_step"])
    pp1f1b_s = float(kv["pp1f1b_s_per_step"])
    fp8_s = float(kv["fp8_s_per_step"])
    reest_s = float(kv["reest_s_per_step"])
    emit("supervisor/pp2_async2", pp_s * 1e6,
         "2-stage pipeline candidate under async supervision")
    emit("supervisor/pp1f1b_async2", pp1f1b_s * 1e6,
         f"real 2-stage/2-microbatch 1F1B engine, per-rank trace merge "
         f"({pp1f1b_s / pp_s:.2f}x the staged pp candidate)")
    emit("supervisor/fp8_tile128_async2", fp8_s * 1e6,
         "fp8 tile128 candidate, BF16-eps thresholds")
    emit("supervisor/reest_async2", reest_s * 1e6,
         f"periodic re-estimation cost {(reest_s - async_s) * 1e3:+.1f} "
         f"ms/step")
    write_json(json_path, rows=ROWS[first_row:])
    ok = async_s <= 2.0 * nocheck and async_s < sync_s
    emit("supervisor/acceptance", 0.0,
         f"{'PASS' if ok else 'FAIL'}: async2 <= 2x unsupervised loop "
         f"and async2 < sync")
    return kv


if __name__ == "__main__":
    run()
