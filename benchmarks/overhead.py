"""Paper §6.4: detection latency — naive loss-curve watching vs TTrace."""
from __future__ import annotations

from benchmarks.common import emit, run_worker


def run():
    out = run_worker("benchmarks.overhead_worker", devices=8, timeout=3600)
    kv = dict(ln.split("\t") for ln in out.strip().splitlines()
              if "\t" in ln)
    print("# " + " | ".join(f"{k}={v}" for k, v in kv.items()))
    emit("overhead.naive_seconds", float(kv["naive_seconds"]) * 1e6,
         f"detect_step={kv['naive_detect_step']} "
         f"gap={kv.get('loss_gap_final', '?')}")
    emit("overhead.ttrace_seconds", float(kv["ttrace_seconds"]) * 1e6,
         f"detected={kv['ttrace_detected']} "
         f"localized={kv['ttrace_localized']}")
    emit("overhead.speedup", 0.0, f"{kv['speedup']}x faster than naive")
    return kv


if __name__ == "__main__":
    run()
