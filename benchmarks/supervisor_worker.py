"""Worker (8 forced host devices): supervisor overhead vs an unsupervised
training loop.

Four loops over the same (arch, parallelism, batch stream):

* ``plain``   — the bare distributed candidate train step: no tracing, no
  reference, no checking (what production training costs);
* ``nocheck`` — the supervisor's lockstep loop with checking off: reference
  + candidate traced steps, no differential checks (the "unsupervised
  loop" the overhead criterion compares against — training both sides is
  the floor the checking policy sits on);
* ``sync``    — supervised run with ``async_window=0``: every step blocks
  on its own differential check before the next step dispatches;
* ``async``   — supervised run with a 2-deep in-flight check window (the
  double-buffered pipeline).

Prints ``key\tvalue`` TSV of steady-state (post-compilation) seconds/step.
Spill is disabled for all timed runs so the rows compare checking policies,
not disk bandwidth; a fourth row times the default spill-enabled ring for
reference.
"""
import os

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

import dataclasses
import time

import jax

from repro.configs.base import get_config
from repro.data.synthetic import make_batch
from repro.models.model import Model
from repro.optim.adamw import AdamW
from repro.parallel.api import ParallelConfig, make_plain_train_step
from repro.supervise import Supervisor, SuperviseConfig

# 24 steady steps: single-shot rows on the 2-core container swing ~20%
# between runs at 18 steps; the longer window tames the ratio rows.
# On top of that every row is best-of-TRIALS (min): the first trial pays
# compilation, later trials hit the jit caches and cost only the steady
# steps, so the repeat is nearly free and strips co-tenant noise spikes
# that single-shot rows keep tripping the acceptance ratios on
STEPS = 3 if os.environ.get("REPRO_BENCH_SMOKE") else 24
TRIALS = 1 if os.environ.get("REPRO_BENCH_SMOKE") else 2
WARM = 2
BATCH, SEQ = 4, 32


def main():
    cfg = dataclasses.replace(get_config("gpt-paper").reduced(),
                              n_layers=2, vocab=512, tie_embeddings=True)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    pcfg = ParallelConfig(dp=2, tp=2)

    # --- unsupervised plain candidate loop ---------------------------------
    opt = AdamW(lr=1e-3)
    step_fn, prep, p, s = make_plain_train_step(cfg, pcfg, params, opt)
    loss = None
    for k in range(WARM):
        p, s, loss = step_fn(p, s, prep(make_batch(cfg, BATCH, SEQ, step=k)))
    loss.block_until_ready()
    plain = float("inf")
    for _ in range(TRIALS):
        t0 = time.perf_counter()
        for k in range(WARM, WARM + STEPS):
            p, s, loss = step_fn(p, s,
                                 prep(make_batch(cfg, BATCH, SEQ, step=k)))
        loss.block_until_ready()
        plain = min(plain, (time.perf_counter() - t0) / STEPS)
    print(f"plain_s_per_step\t{plain:.6f}")

    # --- supervised runs ----------------------------------------------------
    def supervised(window: int, spill: bool, check_every: int = 1,
                   run_pcfg: ParallelConfig = pcfg,
                   reestimate_every: int = 0, journal: bool = False):
        # journal=False for the legacy rows: they time checking policies;
        # the fsync'd journal is priced by its own dedicated row
        best = float("inf")
        for _ in range(TRIALS):
            sup = Supervisor(
                model, cfg, run_pcfg, AdamW(lr=1e-3), params=params,
                scfg=SuperviseConfig(steps=WARM + STEPS,
                                     async_window=window,
                                     check_every=check_every,
                                     reestimate_every=reestimate_every,
                                     spill=spill, ring_window=4,
                                     ckpt_every=WARM + STEPS,
                                     stop_on_flag=False, journal=journal),
                batch_size=BATCH, seq_len=SEQ)
            res = sup.run()
            assert res.passed, ("clean supervised run flagged:\n"
                                + res.summary())
            best = min(best, 1.0 / res.timings["steady_steps_per_s"])
        return best

    # checking off entirely (check_every=0): the bare lockstep loop.  The
    # old form (check_every > run length) was the bench-harness bug behind
    # the "nocheck slower than async2" anomaly: the ring window scales with
    # check_every to honor the pin contract, so EVERY trace of the run
    # stayed live and the loop paid allocator pressure checking never pays
    nocheck = supervised(window=2, spill=False, check_every=0)
    print(f"nocheck_s_per_step\t{nocheck:.6f}")
    sync_s = supervised(window=0, spill=False)
    print(f"sync_s_per_step\t{sync_s:.6f}")
    async_s = supervised(window=2, spill=False)
    print(f"async_s_per_step\t{async_s:.6f}")
    # the fault-tolerance tax: same async loop with the fsync'd per-step
    # journal on (one step + one verdict record per step at this cadence)
    journal_s = supervised(window=2, spill=False, journal=True)
    print(f"journal_s_per_step\t{journal_s:.6f}")
    print(f"journal_overhead_x\t{journal_s / async_s:.3f}")
    spill_s = supervised(window=2, spill=True)
    print(f"async_spill_s_per_step\t{spill_s:.6f}")
    print(f"async_overhead_x\t{async_s / nocheck:.3f}")
    print(f"sync_overhead_x\t{sync_s / nocheck:.3f}")

    # --- recipe-generic supervision: pp / fp8 candidates --------------------
    pp_s = supervised(window=2, spill=False,
                      run_pcfg=ParallelConfig(pp=2))
    print(f"pp_s_per_step\t{pp_s:.6f}")
    # real multi-device 1F1B engine: 2 stages on 2 devices, 2 microbatches,
    # per-rank traces merged before every online check
    pp1f1b_s = supervised(window=2, spill=False,
                          run_pcfg=ParallelConfig(pp=2, pp_schedule="1f1b",
                                                  microbatches=2))
    print(f"pp1f1b_s_per_step\t{pp1f1b_s:.6f}")
    fp8_s = supervised(window=2, spill=False,
                       run_pcfg=ParallelConfig(fp8="tile128"))
    print(f"fp8_s_per_step\t{fp8_s:.6f}")
    # periodic re-estimation overhead on the async dense loop (R = 1/3 run)
    reest_s = supervised(window=2, spill=False,
                         reestimate_every=(WARM + STEPS) // 3)
    print(f"reest_s_per_step\t{reest_s:.6f}")


if __name__ == "__main__":
    main()
