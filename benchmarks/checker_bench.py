"""Checker hot-loop benchmark: per-tensor numpy loop vs the batched engine.

Builds synthetic device-resident trace sections (N tensors, ragged sizes)
and times both comparison paths of core.relerr_engine:

* ``loop``   — the pre-refactor semantics: pull each tensor to host, float64
  norms, one pair at a time;
* ``packed`` — the batched device path the engine auto-selects for large
  sections (packed segmented Pallas kernel on TPU, fused one-dispatch XLA
  reduction elsewhere).

Emits the usual CSV rows and writes ``BENCH_checker.json``
(name -> us_per_call) so the speedup is a tracked trajectory, not a claim.
Row names are stable across backends — ``checker/packed/...`` always means
"the engine's batched path"; WHICH executor ran (packed kernel / blas /
fused) is recorded in the CSV ``derived`` column, so trajectories from
different backends are comparable by row but attributable by mode.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import ROWS, emit, timeit, write_json
from repro.core.relerr_engine import batched_rel_err

# (n_tensors, total_elements): the large case models a trace section of the
# bigger configs (deepseek_v2_236b / qwen15_110b scale per-tensor sizes,
# where the old loop's float64 temporaries spill out of cache); the small
# case tracks where the numpy loop still wins (and why the engine keeps the
# size cutoff).
CASES = [
    (50, 1 << 17),
    (200, 1 << 22),
    (200, 1 << 26),
]


def _make_sections(n_tensors: int, total: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    w = rng.uniform(0.5, 1.5, n_tensors)
    sizes = np.maximum(1, (w * (total / w.sum())).astype(int))
    sec_a, sec_b = {}, {}
    for i, n in enumerate(sizes):
        a = rng.standard_normal(n).astype(np.float32)
        b = a + 1e-4 * rng.standard_normal(n).astype(np.float32)
        sec_a[f"t{i}"] = jnp.asarray(a)
        sec_b[f"t{i}"] = jnp.asarray(b)
    return sec_a, sec_b


def run(json_path: str = "BENCH_checker.json") -> None:
    backend = jax.default_backend()
    batched_mode = {"tpu": "packed", "cpu": "blas"}.get(backend, "fused")
    first_row = len(ROWS)
    for n_tensors, total in CASES:
        sec_a, sec_b = _make_sections(n_tensors, total)
        label = f"{n_tensors}x{total // 1024}k"
        t_loop = timeit(
            lambda: batched_rel_err(sec_a, sec_b, mode="loop"), iters=5)
        t_batched = timeit(
            lambda: batched_rel_err(sec_a, sec_b, mode=batched_mode),
            iters=5)
        # the engine's auto selection (per-pair mean crossover on CPU) must
        # track the better executor — the regression row: auto far above
        # min(loop, batched) means the crossover rotted
        t_auto = timeit(lambda: batched_rel_err(sec_a, sec_b), iters=5)
        emit(f"checker/loop/{label}", t_loop)
        emit(f"checker/packed/{label}", t_batched,
             derived=f"speedup={t_loop / t_batched:.2f}x "
                     f"mode={batched_mode}")
        best = min(t_loop, t_batched)
        emit(f"checker/auto/{label}", t_auto,
             derived=f"vs_best={t_auto / best:.2f}x "
                     f"({'OK' if t_auto <= 1.25 * best else 'REGRESSED'})")
    if json_path:
        write_json(json_path, rows=ROWS[first_row:])


if __name__ == "__main__":
    run()
