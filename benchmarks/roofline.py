"""Roofline analysis (deliverable g).

XLA's cost_analysis counts while-loop bodies ONCE (verified empirically), so
full-graph numbers from the scanned/microbatched train step undercount by the
trip counts.  Instead we lower each COMPONENT unrolled — one transformer
block per segment kind (fwd+bwd for training), the embed+head+CE stem, the
optimizer step — on the production mesh with the production shardings, read
cost_analysis + collective bytes from each compiled artifact, and compose:

    total = n_micro * (sum_seg count_seg * block_cost + stem) + opt_step

Per (arch x shape), three per-device roofline terms on TPU v5e:
    compute    = FLOPs / 197e12           (bf16 MXU peak per chip)
    memory     = bytes_accessed / 819e9   (HBM bandwidth)
    collective = collective operand bytes / 50e9  (ICI per link)

plus MODEL_FLOPS = 6*N_active*D (train) or 2*N_active*D (prefill/decode) and
the useful-compute ratio.  All numbers are per device; HLO shapes are
post-SPMD (local shards), so no further division by chip count applies.
"""
from __future__ import annotations

import dataclasses
import json
import os

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9
GB = 1 << 30


def run(out_json="roofline_report.json", multi_pod=False, archs=None,
        shapes=None):
    from benchmarks.common import emit, run_worker
    args = []
    if archs:
        args += ["--archs", ",".join(archs)]
    if shapes:
        args += ["--shapes", ",".join(shapes)]
    out = run_worker("benchmarks.roofline_worker", *args, devices=512,
                     timeout=7200)
    recs = []
    for ln in out.splitlines():
        if ln.startswith("{"):
            recs.append(json.loads(ln))
        elif ln.strip():
            print("#", ln)
    with open(out_json, "w") as f:
        json.dump(recs, f, indent=1)
    for r in recs:
        if r.get("status") != "ok":
            continue
        name = f"roofline.{r['arch']}.{r['shape']}"
        dom = r["dominant"]
        from benchmarks.common import emit
        emit(name, r["terms"][dom] * 1e6,
             f"dom={dom} c={r['terms']['compute']:.2e}s "
             f"m={r['terms']['memory']:.2e}s "
             f"x={r['terms']['collective']:.2e}s "
             f"useful={r['useful_ratio']:.2f}")
    print(f"wrote {out_json} ({len(recs)} records)")
    return recs


if __name__ == "__main__":
    run()
