"""Paper Table 1: silent-bug detection & localization sweep."""
from __future__ import annotations

from benchmarks.common import emit, run_worker


def run():
    out = run_worker("benchmarks.bug_table_worker", devices=8, timeout=3600)
    rows = [ln.split("\t") for ln in out.strip().splitlines()
            if "\t" in ln]
    n = len(rows)
    det = sum(1 for r in rows if r[3] == "True")
    loc = sum(1 for r in rows if r[6] == "True")
    clean = sum(1 for r in rows if r[2] == "True")
    total_s = sum(float(r[7]) for r in rows)
    print(f"# bug_id type clean detected localized expected loc_ok secs")
    for r in rows:
        print("# " + " ".join(r))
    emit("bug_table.detected", total_s / max(n, 1) * 1e6,
         f"{det}/{n} detected")
    emit("bug_table.localized", total_s / max(n, 1) * 1e6,
         f"{loc}/{n} correctly localized")
    emit("bug_table.clean_pass", total_s / max(n, 1) * 1e6,
         f"{clean}/{n} clean configs pass")
    return {"rows": rows, "detected": det, "localized": loc, "n": n}


if __name__ == "__main__":
    run()
