"""Paper Fig 7 + Fig 8: threshold curves vs depth and bug/FP separation."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, run_worker


def run(L=16):
    out = run_worker("benchmarks.curves_worker", L, devices=8, timeout=3600)
    data: dict[str, list[float]] = {}
    for ln in out.strip().splitlines():
        parts = ln.split("\t")
        if len(parts) != 4:
            continue
        sec, li, role, val = parts
        data.setdefault(f"{sec}.{role}", []).append(float(val))

    def stats(key):
        v = data.get(key, [])
        return (v[0], v[-1], max(v)) if v else (0, 0, 0)

    # Fig 7: estimated FP thresholds grow slowly with depth (smoothness)
    for key in ("est_act.attn_out", "est_act.mlp_out", "est_agrad.mlp_out",
                "est_pgrad.qkv_w"):
        f, l, mx = stats(key)
        emit(f"fig7.{key}", 0.0,
             f"rel/eps first={f:.2f} last={l:.2f} max={mx:.2f}")
    # Fig 8: separation — distributed-correct ~ eps; bugs ~ 100 eps
    d_f, d_l, d_mx = stats("dist_act.mlp_out")
    b_f, b_l, b_mx = stats("bugfwd_act.mlp_out")
    emit("fig8.fp_error_distributed", 0.0,
         f"rel/eps max={d_mx:.2f}")
    emit("fig8.bug_error_forward", 0.0,
         f"rel/eps max={b_mx:.2f} separation={b_mx / max(d_mx, 1e-9):.0f}x")
    gb_mx = stats("bugbwd_pgrad.proj_w")[2]
    emit("fig8.bug_error_backward_pgrad", 0.0, f"rel/eps max={gb_mx:.2f}")
    # smoothness claim (Thm 5.1/5.2): no exponential blow-up across depth
    growth = stats("est_act.mlp_out")[1] / max(stats("est_act.mlp_out")[0],
                                               1e-9)
    emit("fig7.depth_growth_factor", 0.0,
         f"last/first={growth:.2f} (linear-ish, not exponential)")
    return data


if __name__ == "__main__":
    run()
